//! `CompileCache` — interior-mutable get-or-compile executor cache
//! shared by the single-session [`crate::coordinator::router::Engine`]
//! and the multi-stream [`crate::coordinator::server::Server`].
//!
//! Compilation is a one-off cost the paper keeps off the frame path
//! (kernels are built before the stream starts); here that discipline
//! is a `&self` cache: the first request for an artifact compiles it
//! under the lock, every later request clones an `Arc` handle.
//! Failures are negatively cached so a missing/broken HLO file is read
//! once, not once per frame, on the fallback path.
//!
//! Concurrency note: the offline build's `xla` stub types are plain
//! data, so sharing executors behind `Arc` is sound.  A real PJRT
//! backend with non-`Sync` FFI handles must keep per-thread executors
//! instead (the [`crate::runtime::device_pool`] model); this cache is
//! the single place that decision lives.

use crate::runtime::artifact::{ArtifactManifest, ArtifactMeta};
use crate::runtime::client::HistogramExecutor;
use crate::histogram::types::Strategy;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, MutexGuard};

#[derive(Default)]
struct CacheState {
    compiled: HashMap<String, Arc<HistogramExecutor>>,
    /// Artifacts whose compile failed — negatively cached so the
    /// per-frame fallback path never re-reads the HLO file.
    failed: HashSet<String>,
    /// Memoized (strategy, h, w, bins) → manifest-match results, so
    /// hot fallback paths can test availability without re-scanning
    /// the manifest or building error strings per frame.
    strategy_known: HashMap<(Strategy, usize, usize, usize), bool>,
}

/// Thread-safe executor cache over one artifact manifest.
pub struct CompileCache {
    manifest: Arc<ArtifactManifest>,
    state: Mutex<CacheState>,
}

impl CompileCache {
    pub fn new(manifest: Arc<ArtifactManifest>) -> CompileCache {
        CompileCache { manifest, state: Mutex::new(CacheState::default()) }
    }

    pub fn manifest(&self) -> &Arc<ArtifactManifest> {
        &self.manifest
    }

    fn lock(&self) -> MutexGuard<'_, CacheState> {
        self.state.lock().expect("compile cache lock")
    }

    /// Get-or-compile `meta`, returning a shared executor handle.
    pub fn get_or_compile(&self, meta: &ArtifactMeta) -> Result<Arc<HistogramExecutor>> {
        let mut st = self.lock();
        if let Some(exe) = st.compiled.get(&meta.name) {
            return Ok(Arc::clone(exe));
        }
        if st.failed.contains(&meta.name) {
            return Err(anyhow!("artifact '{}' previously failed to compile", meta.name));
        }
        // Compile under the lock: concurrent first requests for one
        // artifact would otherwise compile it twice (compiles are rare
        // one-offs; serving threads are on the CPU path meanwhile).
        match HistogramExecutor::compile(&self.manifest, meta) {
            Ok(exe) => {
                let exe = Arc::new(exe);
                st.compiled.insert(meta.name.clone(), Arc::clone(&exe));
                Ok(exe)
            }
            Err(e) => {
                st.failed.insert(meta.name.clone());
                Err(e)
            }
        }
    }

    /// Find the artifact for (strategy, geometry, bins) and compile it,
    /// with the actionable "no artifact" error when none matches.
    pub fn strategy_executor(
        &self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Result<Arc<HistogramExecutor>> {
        let meta = self
            .manifest
            .find_strategy(strategy, h, w, bins)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {strategy} {h}x{w} bins={bins}; available: {}",
                    self.manifest
                        .strategies()
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        self.get_or_compile(&meta)
    }

    /// Whether a strategy artifact matching (strategy, h, w, bins)
    /// exists in the manifest — memoized, allocation-free after the
    /// first lookup per geometry, so per-frame fallback routing stays
    /// off the allocator.
    pub fn has_strategy(&self, strategy: Strategy, h: usize, w: usize, bins: usize) -> bool {
        let mut st = self.lock();
        if let Some(&known) = st.strategy_known.get(&(strategy, h, w, bins)) {
            return known;
        }
        let known = self.manifest.find_strategy(strategy, h, w, bins).is_some();
        st.strategy_known.insert((strategy, h, w, bins), known);
        known
    }

    /// Number of successfully compiled executors held.
    pub fn compiled_count(&self) -> usize {
        self.lock().compiled.len()
    }

    /// Drop every cached executor and negative compile result — call
    /// after regenerating `artifacts/` so failed compiles are retried.
    pub fn clear(&self) {
        let mut st = self.lock();
        st.compiled.clear();
        st.failed.clear();
        st.strategy_known.clear();
    }
}

impl std::fmt::Debug for CompileCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.lock();
        f.debug_struct("CompileCache")
            .field("compiled", &st.compiled.len())
            .field("failed", &st.failed.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn empty_manifest() -> Arc<ArtifactManifest> {
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    #[test]
    fn missing_strategy_is_helpful_error() {
        let cache = CompileCache::new(empty_manifest());
        let err = cache
            .strategy_executor(Strategy::WfTis, 64, 64, 32)
            .err()
            .expect("must fail")
            .to_string();
        assert!(err.contains("no artifact"), "{err}");
        assert_eq!(cache.compiled_count(), 0);
    }

    #[test]
    fn clear_resets_state() {
        let cache = CompileCache::new(empty_manifest());
        let _ = cache.strategy_executor(Strategy::WfTis, 8, 8, 4);
        cache.clear();
        assert_eq!(cache.compiled_count(), 0);
    }

    #[test]
    fn has_strategy_memoizes_misses() {
        let cache = CompileCache::new(empty_manifest());
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
        // Second call answers from the memo (observably: still false,
        // no state change).
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
        cache.clear();
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
    }
}
