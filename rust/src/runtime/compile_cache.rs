//! `CompileCache` — interior-mutable get-or-compile executor cache
//! shared by the single-session [`crate::coordinator::router::Engine`]
//! and the multi-stream [`crate::coordinator::server::Server`].
//!
//! Compilation is a one-off cost the paper keeps off the frame path
//! (kernels are built before the stream starts); here that discipline
//! is a `&self` cache: the first request for an artifact compiles it
//! under the lock, every later request clones an `Arc` handle.
//! Failures are negatively cached so a missing/broken HLO file is read
//! once, not once per frame, on the fallback path.
//!
//! Transient-failure handling (DESIGN.md §8): a [`RetryPolicy`] can
//! re-run a failed compile with exponential backoff before the
//! negative cache takes over, and negative entries can carry a TTL
//! after which one fresh attempt is allowed ("redemption") — so a
//! driver hiccup at startup does not permanently demote an artifact
//! to the CPU path.  Defaults (`attempts == 1`, no TTL) reproduce the
//! original compile-once-then-negative-cache behaviour exactly.
//!
//! Concurrency note: the offline build's `xla` stub types are plain
//! data, so sharing executors behind `Arc` is sound.  A real PJRT
//! backend with non-`Sync` FFI handles must keep per-thread executors
//! instead (the [`crate::runtime::device_pool`] model); this cache is
//! the single place that decision lives — [`ExecutorScope::PerThread`]
//! keys every entry (positive *and* negative) by the calling thread,
//! so an executor `Arc` handed out on one thread is never the instance
//! another thread compiled, while the `Arc<HistogramExecutor>` API the
//! routers consume stays unchanged (DESIGN.md §5).

use crate::fault::{FaultAction, FaultInjector, FaultSite};
use crate::histogram::types::Strategy;
use crate::runtime::artifact::{ArtifactManifest, ArtifactMeta};
use crate::runtime::client::HistogramExecutor;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::ThreadId;
use std::time::{Duration, Instant};

/// How compiled executors may be shared across threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorScope {
    /// One executor per artifact, shared via `Arc` (sound for the
    /// offline stub and any `Sync` backend).
    #[default]
    Shared,
    /// One executor per (thread, artifact): required when the backend's
    /// FFI handles are not `Sync` — each serving thread compiles and
    /// owns its own executable, like one CUDA context per device.
    PerThread,
}

/// Transient-failure policy for compiles (and, via
/// [`crate::runtime::device_pool::DevicePolicy`], executions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total compile attempts per request before the failure is
    /// negatively cached.  `1` = no retry (the original behaviour).
    pub attempts: usize,
    /// Sleep before attempt `k+1` is `backoff << k` — exponential,
    /// starting at this base.  Compiles are pre-stream one-offs, so
    /// the sleep happens under the cache lock by design (same as the
    /// compile itself); keep the base small.
    pub backoff: Duration,
    /// If set, a negatively cached artifact older than this TTL is
    /// granted one fresh attempt ("redemption") instead of the cached
    /// error.  `None` = negative entries are permanent until
    /// [`CompileCache::clear`].
    pub negative_ttl: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { attempts: 1, backoff: Duration::from_millis(10), negative_ttl: None }
    }
}

/// Outer cache key: `None` in [`ExecutorScope::Shared`] mode, the
/// calling thread in [`ExecutorScope::PerThread`] mode.  Inner maps
/// key by artifact name, so steady-state hits look up with a borrowed
/// `&str` — no per-request allocation.
type ScopeKey = Option<ThreadId>;

#[derive(Default)]
struct CacheState {
    compiled: HashMap<ScopeKey, HashMap<String, Arc<HistogramExecutor>>>,
    /// Artifacts whose compile failed, with the failure time — the
    /// negative cache keeps the per-frame fallback path from re-reading
    /// the HLO file, and the timestamp drives [`RetryPolicy`]'s
    /// negative-TTL redemption.
    failed: HashMap<ScopeKey, HashMap<String, Instant>>,
    /// Memoized (strategy, h, w, bins) → manifest-match results, so
    /// hot fallback paths can test availability without re-scanning
    /// the manifest or building error strings per frame.  Manifest
    /// lookups are thread-independent, so this map never keys by
    /// thread.
    strategy_known: HashMap<(Strategy, usize, usize, usize), bool>,
}

/// Thread-safe executor cache over one artifact manifest.
pub struct CompileCache {
    manifest: Arc<ArtifactManifest>,
    scope: ExecutorScope,
    retry: RetryPolicy,
    faults: Option<Arc<FaultInjector>>,
    state: Mutex<CacheState>,
    /// Actual `HistogramExecutor::compile` invocations — the
    /// observable difference between the scopes (PerThread compiles
    /// once per thread, Shared once per process).
    compile_attempts: AtomicUsize,
    /// Attempts beyond the first within a single request (retries).
    compile_retries: AtomicUsize,
    /// Negative-cache entries expired by `negative_ttl` and granted a
    /// fresh attempt.
    negative_redemptions: AtomicUsize,
}

impl CompileCache {
    pub fn new(manifest: Arc<ArtifactManifest>) -> CompileCache {
        Self::with_scope(manifest, ExecutorScope::Shared)
    }

    pub fn with_scope(manifest: Arc<ArtifactManifest>, scope: ExecutorScope) -> CompileCache {
        Self::with_policy(manifest, scope, RetryPolicy::default())
    }

    pub fn with_policy(
        manifest: Arc<ArtifactManifest>,
        scope: ExecutorScope,
        retry: RetryPolicy,
    ) -> CompileCache {
        CompileCache {
            manifest,
            scope,
            retry,
            faults: None,
            state: Mutex::new(CacheState::default()),
            compile_attempts: AtomicUsize::new(0),
            compile_retries: AtomicUsize::new(0),
            negative_redemptions: AtomicUsize::new(0),
        }
    }

    /// Wire a fault injector: each compile attempt consults
    /// [`FaultSite::Compile`] and treats an injected `Error` as a
    /// failed attempt (retried / negatively cached like a real one).
    pub fn set_faults(&mut self, faults: Arc<FaultInjector>) {
        self.faults = Some(faults);
    }

    pub fn manifest(&self) -> &Arc<ArtifactManifest> {
        &self.manifest
    }

    pub fn scope(&self) -> ExecutorScope {
        self.scope
    }

    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// `HistogramExecutor::compile` calls performed so far.
    pub fn compile_attempts(&self) -> usize {
        self.compile_attempts.load(Ordering::Relaxed)
    }

    /// Retry attempts (attempts beyond the first per request).
    pub fn compile_retries(&self) -> usize {
        self.compile_retries.load(Ordering::Relaxed)
    }

    /// Negative-cache entries redeemed after their TTL.
    pub fn negative_redemptions(&self) -> usize {
        self.negative_redemptions.load(Ordering::Relaxed)
    }

    fn lock(&self) -> MutexGuard<'_, CacheState> {
        // Cache maps are valid at every instruction boundary (inserts
        // of complete entries), so a poisoned lock is recovered, not
        // propagated — a panicking compile thread must not wedge every
        // serving thread behind it (DESIGN.md §8).
        lock_recover(&self.state)
    }

    fn scope_key(&self) -> ScopeKey {
        match self.scope {
            ExecutorScope::Shared => None,
            ExecutorScope::PerThread => Some(std::thread::current().id()),
        }
    }

    /// Get-or-compile `meta`, returning a shared executor handle (in
    /// `PerThread` scope: shared only with this thread's later calls).
    /// Steady-state hits allocate nothing (borrowed-name lookups).
    pub fn get_or_compile(&self, meta: &ArtifactMeta) -> Result<Arc<HistogramExecutor>> {
        let scope = self.scope_key();
        let mut st = self.lock();
        if let Some(exe) = st.compiled.get(&scope).and_then(|m| m.get(meta.name.as_str())) {
            return Ok(Arc::clone(exe));
        }
        if let Some(&when) = st.failed.get(&scope).and_then(|m| m.get(meta.name.as_str())) {
            let redeemed = self.retry.negative_ttl.is_some_and(|ttl| when.elapsed() >= ttl);
            if !redeemed {
                return Err(anyhow!("artifact '{}' previously failed to compile", meta.name));
            }
            // TTL expired: drop the entry and fall through to one
            // fresh round of attempts.
            if let Some(m) = st.failed.get_mut(&scope) {
                m.remove(meta.name.as_str());
            }
            self.negative_redemptions.fetch_add(1, Ordering::Relaxed);
        }
        // Compile under the lock: concurrent first requests for one
        // artifact would otherwise compile it twice (compiles are rare
        // one-offs; serving threads are on the CPU path meanwhile).
        let attempts = self.retry.attempts.max(1);
        let mut last_err = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.compile_retries.fetch_add(1, Ordering::Relaxed);
                let factor = 1u32 << (attempt - 1).min(16);
                let pause = self.retry.backoff.saturating_mul(factor);
                if !pause.is_zero() {
                    std::thread::sleep(pause);
                }
            }
            self.compile_attempts.fetch_add(1, Ordering::Relaxed);
            if let Some(fi) = &self.faults {
                if matches!(fi.decide(FaultSite::Compile), Some(FaultAction::Error)) {
                    last_err = Some(anyhow!("injected compile failure for '{}'", meta.name));
                    continue;
                }
            }
            match HistogramExecutor::compile(&self.manifest, meta) {
                Ok(exe) => {
                    let exe = Arc::new(exe);
                    st.compiled
                        .entry(scope)
                        .or_default()
                        .insert(meta.name.clone(), Arc::clone(&exe));
                    return Ok(exe);
                }
                Err(e) => last_err = Some(e),
            }
        }
        st.failed.entry(scope).or_default().insert(meta.name.clone(), Instant::now());
        Err(last_err.unwrap_or_else(|| anyhow!("compile of '{}' failed", meta.name)))
    }

    /// Find the artifact for (strategy, geometry, bins) and compile it,
    /// with the actionable "no artifact" error when none matches.
    pub fn strategy_executor(
        &self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Result<Arc<HistogramExecutor>> {
        let meta = self
            .manifest
            .find_strategy(strategy, h, w, bins)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {strategy} {h}x{w} bins={bins}; available: {}",
                    self.manifest
                        .strategies()
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        self.get_or_compile(&meta)
    }

    /// Whether a strategy artifact matching (strategy, h, w, bins)
    /// exists in the manifest — memoized, allocation-free after the
    /// first lookup per geometry, so per-frame fallback routing stays
    /// off the allocator.
    pub fn has_strategy(&self, strategy: Strategy, h: usize, w: usize, bins: usize) -> bool {
        let mut st = self.lock();
        if let Some(&known) = st.strategy_known.get(&(strategy, h, w, bins)) {
            return known;
        }
        let known = self.manifest.find_strategy(strategy, h, w, bins).is_some();
        st.strategy_known.insert((strategy, h, w, bins), known);
        known
    }

    /// Number of successfully compiled executors held (in `PerThread`
    /// scope this counts per-thread instances).
    pub fn compiled_count(&self) -> usize {
        self.lock().compiled.values().map(|m| m.len()).sum()
    }

    /// Drop the calling thread's cache entries (positive and
    /// negative).  `ThreadId`s are never reused, so a `PerThread`-scope
    /// cache in a thread-per-request system must call this before a
    /// worker thread exits or dead threads' executors accumulate
    /// forever.  No-op in `Shared` scope.
    pub fn evict_current_thread(&self) {
        if self.scope != ExecutorScope::PerThread {
            return;
        }
        let tid = Some(std::thread::current().id());
        let mut st = self.lock();
        st.compiled.remove(&tid);
        st.failed.remove(&tid);
    }

    /// Drop every cached executor and negative compile result — call
    /// after regenerating `artifacts/` so failed compiles are retried.
    pub fn clear(&self) {
        let mut st = self.lock();
        st.compiled.clear();
        st.failed.clear();
        st.strategy_known.clear();
    }
}

impl std::fmt::Debug for CompileCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.lock();
        f.debug_struct("CompileCache")
            .field("scope", &self.scope)
            .field("compiled", &st.compiled.values().map(|m| m.len()).sum::<usize>())
            .field("failed", &st.failed.values().map(|m| m.len()).sum::<usize>())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn empty_manifest() -> Arc<ArtifactManifest> {
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    fn fake_meta(name: &str) -> ArtifactMeta {
        ArtifactMeta {
            name: name.into(),
            kind: crate::runtime::artifact::ArtifactKind::Strategy,
            strategy: "wf_tis".into(),
            height: 8,
            width: 8,
            padded_h: 8,
            padded_w: 8,
            bins: 4,
            tile: 8,
            n_rects: 0,
            file: format!("{name}.hlo"),
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn missing_strategy_is_helpful_error() {
        let cache = CompileCache::new(empty_manifest());
        let err = cache
            .strategy_executor(Strategy::WfTis, 64, 64, 32)
            .err()
            .expect("must fail")
            .to_string();
        assert!(err.contains("no artifact"), "{err}");
        assert_eq!(cache.compiled_count(), 0);
    }

    #[test]
    fn clear_resets_state() {
        let cache = CompileCache::new(empty_manifest());
        let _ = cache.strategy_executor(Strategy::WfTis, 8, 8, 4);
        cache.clear();
        assert_eq!(cache.compiled_count(), 0);
    }

    #[test]
    fn has_strategy_memoizes_misses() {
        let cache = CompileCache::new(empty_manifest());
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
        // Second call answers from the memo (observably: still false,
        // no state change).
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
        cache.clear();
        assert!(!cache.has_strategy(Strategy::WfTis, 64, 64, 32));
    }

    /// Shared scope: one compile attempt serves every thread (the
    /// second request hits the negative cache — in a real-backend
    /// build it would clone the compiled `Arc`).
    #[test]
    fn shared_scope_compiles_once_across_threads() {
        let cache = CompileCache::new(empty_manifest());
        let meta = fake_meta("wf_tis_8x8_b4_t8");
        assert!(cache.get_or_compile(&meta).is_err(), "offline compile fails");
        assert_eq!(cache.compile_attempts(), 1);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let cache = &cache;
                let meta = meta.clone();
                s.spawn(move || {
                    assert!(cache.get_or_compile(&meta).is_err());
                });
            }
        });
        assert_eq!(cache.compile_attempts(), 1, "shared negative cache answers all threads");
    }

    /// PerThread scope: every thread runs its own compile and owns its
    /// own (here: negative) cache entry — the isolation a non-`Sync`
    /// PJRT build requires.
    #[test]
    fn per_thread_scope_compiles_once_per_thread() {
        let cache = CompileCache::with_scope(empty_manifest(), ExecutorScope::PerThread);
        assert_eq!(cache.scope(), ExecutorScope::PerThread);
        let meta = fake_meta("wf_tis_8x8_b4_t8");
        std::thread::scope(|s| {
            for i in 0..3 {
                let cache = &cache;
                let meta = meta.clone();
                s.spawn(move || {
                    // Two calls on one thread: one attempt, then the
                    // thread's own negative cache.
                    assert!(cache.get_or_compile(&meta).is_err());
                    assert!(cache.get_or_compile(&meta).is_err());
                    if i == 0 {
                        // A departing worker clears its own entries.
                        cache.evict_current_thread();
                    }
                });
            }
        });
        assert_eq!(
            cache.compile_attempts(),
            3,
            "each thread must perform exactly one compile of its own"
        );
        // The calling thread has no entry yet: its request is a fresh
        // attempt, not a hit on another thread's entry.
        assert!(cache.get_or_compile(&meta).is_err());
        assert_eq!(cache.compile_attempts(), 4);
    }

    /// A retrying policy burns all attempts before negatively caching,
    /// and the negative cache then answers without further attempts.
    #[test]
    fn retry_policy_exhausts_attempts_then_caches() {
        let retry = RetryPolicy { attempts: 3, backoff: Duration::ZERO, negative_ttl: None };
        let cache = CompileCache::with_policy(empty_manifest(), ExecutorScope::Shared, retry);
        let meta = fake_meta("wf_tis_8x8_b4_t8");
        assert!(cache.get_or_compile(&meta).is_err());
        assert_eq!(cache.compile_attempts(), 3, "all attempts consumed");
        assert_eq!(cache.compile_retries(), 2);
        assert!(cache.get_or_compile(&meta).is_err());
        assert_eq!(cache.compile_attempts(), 3, "second request is a pure negative hit");
        assert_eq!(cache.negative_redemptions(), 0);
    }

    /// An expired negative entry earns exactly one fresh round of
    /// attempts (redemption), then is re-cached.
    #[test]
    fn negative_ttl_redeems_expired_entries() {
        let retry = RetryPolicy {
            attempts: 1,
            backoff: Duration::ZERO,
            negative_ttl: Some(Duration::ZERO),
        };
        let cache = CompileCache::with_policy(empty_manifest(), ExecutorScope::Shared, retry);
        let meta = fake_meta("wf_tis_8x8_b4_t8");
        assert!(cache.get_or_compile(&meta).is_err());
        assert_eq!(cache.compile_attempts(), 1);
        // TTL of zero: the entry is immediately redeemable.
        assert!(cache.get_or_compile(&meta).is_err());
        assert_eq!(cache.compile_attempts(), 2, "redeemed entry retried the compile");
        assert_eq!(cache.negative_redemptions(), 1);
    }
}
