//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! The request path is pure Rust: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Python produced the `artifacts/*.hlo.txt` files once at build time
//! (`make artifacts`); nothing here shells out or interprets anything.
//!
//! * [`artifact`] — the manifest (`artifacts/manifest.json`) describing
//!   every lowered module's strategy, geometry and I/O signature.
//! * [`client`] — [`client::HistogramExecutor`]: one compiled executable
//!   bound to one artifact, with typed image→tensor entry points.
//! * [`compile_cache`] — interior-mutable get-or-compile cache shared
//!   by the router and the multi-stream server (compile once, serve
//!   from `Arc` handles, negatively cache failures).
//! * [`device_pool`] — N worker threads each owning a PJRT client
//!   (the paper's multi-GPU substitute), consumed by the coordinator's
//!   bin task queue.

pub mod artifact;
pub mod client;
pub mod compile_cache;
pub mod device_pool;
