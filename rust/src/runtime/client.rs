//! `HistogramExecutor` — one compiled PJRT executable bound to one
//! artifact, with typed entry points for the coordinator.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  The executor owns its `PjRtClient`;
//! clients are cheap on CPU and per-thread ownership sidesteps the
//! crate's non-`Sync` FFI handles (each pipeline lane / pool worker
//! builds its own executors, mirroring one CUDA context per device).

use crate::histogram::region::Rect;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::{ArtifactKind, ArtifactManifest, ArtifactMeta};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::time::{Duration, Instant};

/// A compiled artifact ready to execute.
pub struct HistogramExecutor {
    meta: ArtifactMeta,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl HistogramExecutor {
    /// Compile `meta`'s HLO file on a fresh CPU PJRT client.
    pub fn compile(manifest: &ArtifactManifest, meta: &ArtifactMeta) -> Result<HistogramExecutor> {
        Self::compile_path(&manifest.path_of(meta), meta.clone())
    }

    /// Compile from an explicit path (tests, ad-hoc modules).
    pub fn compile_path(path: &Path, meta: ArtifactMeta) -> Result<HistogramExecutor> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compile {}", meta.name))?;
        Ok(HistogramExecutor { meta, client, exe })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compute the integral histogram of `img` (strategy/init artifacts).
    ///
    /// The image is padded to the artifact's padded geometry (§3.4) and
    /// the result cropped back to the true extent.  Returns the tensor
    /// plus the pure on-device execution time (the "kernel time" every
    /// figure reports, excluding modeled transfers).
    pub fn compute_timed(&self, img: &BinnedImage) -> Result<(IntegralHistogram, Duration)> {
        if !matches!(self.meta.kind, ArtifactKind::Strategy | ArtifactKind::Init) {
            bail!("artifact {} is not a strategy/init module", self.meta.name);
        }
        let lit = self.image_literal(img)?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let kernel_time = t0.elapsed();
        let out = result.to_tuple1().context("unwrap 1-tuple output")?;
        let ih = self.literal_to_ih(&out)?;
        Ok((ih, kernel_time))
    }

    /// [`Self::compute_timed`] without the timing.
    pub fn compute(&self, img: &BinnedImage) -> Result<IntegralHistogram> {
        Ok(self.compute_timed(img)?.0)
    }

    /// Fused serve graph: integral histogram + batched region queries.
    /// `rects` is truncated/padded to the artifact's fixed batch size
    /// (padding repeats the last rect; callers slice the result).
    pub fn compute_with_queries(
        &self,
        img: &BinnedImage,
        rects: &[Rect],
    ) -> Result<(IntegralHistogram, Vec<Vec<f32>>, Duration)> {
        if self.meta.kind != ArtifactKind::Serve {
            bail!("artifact {} is not a serve module", self.meta.name);
        }
        if rects.is_empty() {
            bail!("serve call needs at least one rect");
        }
        let n = self.meta.n_rects;
        let img_lit = self.image_literal(img)?;
        let mut quad = Vec::with_capacity(n * 4);
        for i in 0..n {
            let r = rects[i.min(rects.len() - 1)];
            quad.extend_from_slice(&r.encode());
        }
        let rect_lit = xla::Literal::vec1(quad.as_slice()).reshape(&[n as i64, 4])?;
        let t0 = Instant::now();
        let result =
            self.exe.execute::<xla::Literal>(&[img_lit, rect_lit])?[0][0].to_literal_sync()?;
        let kernel_time = t0.elapsed();
        let (ih_lit, hists_lit) = result.to_tuple2().context("unwrap 2-tuple output")?;
        let ih = self.literal_to_ih(&ih_lit)?;
        let flat = hists_lit.to_vec::<f32>()?;
        let bins = self.meta.bins;
        let hists = flat.chunks(bins).take(rects.len()).map(|c| c.to_vec()).collect();
        Ok((ih, hists, kernel_time))
    }

    /// Batched Eq. 2 lookups against a precomputed tensor (query artifacts).
    pub fn query(&self, ih: &IntegralHistogram, rects: &[Rect]) -> Result<Vec<Vec<f32>>> {
        if self.meta.kind != ArtifactKind::Query {
            bail!("artifact {} is not a query module", self.meta.name);
        }
        if rects.is_empty() {
            return Ok(Vec::new());
        }
        let n = self.meta.n_rects;
        let ih_lit = xla::Literal::vec1(ih.data.as_slice()).reshape(&[
            self.meta.bins as i64,
            self.meta.padded_h as i64,
            self.meta.padded_w as i64,
        ])?;
        let mut quad = Vec::with_capacity(n * 4);
        for i in 0..n {
            let r = rects[i.min(rects.len() - 1)];
            quad.extend_from_slice(&r.encode());
        }
        let rect_lit = xla::Literal::vec1(quad.as_slice()).reshape(&[n as i64, 4])?;
        let result =
            self.exe.execute::<xla::Literal>(&[ih_lit, rect_lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let flat = out.to_vec::<f32>()?;
        Ok(flat.chunks(self.meta.bins).take(rects.len()).map(|c| c.to_vec()).collect())
    }

    /// Build the padded image literal for this artifact.
    fn image_literal(&self, img: &BinnedImage) -> Result<xla::Literal> {
        if img.h != self.meta.height || img.w != self.meta.width {
            bail!(
                "image {}x{} does not match artifact {} ({}x{})",
                img.h,
                img.w,
                self.meta.name,
                self.meta.height,
                self.meta.width
            );
        }
        let padded;
        let data: &[i32] = if (img.h, img.w) == (self.meta.padded_h, self.meta.padded_w) {
            &img.data
        } else {
            padded = pad_image(img, self.meta.padded_h, self.meta.padded_w);
            &padded
        };
        Ok(xla::Literal::vec1(data)
            .reshape(&[self.meta.padded_h as i64, self.meta.padded_w as i64])?)
    }

    /// Convert the output literal into a cropped [`IntegralHistogram`].
    fn literal_to_ih(&self, lit: &xla::Literal) -> Result<IntegralHistogram> {
        let flat = lit.to_vec::<f32>()?;
        let full = IntegralHistogram::from_raw(
            self.meta.bins,
            self.meta.padded_h,
            self.meta.padded_w,
            flat,
        );
        Ok(if (self.meta.height, self.meta.width) == (self.meta.padded_h, self.meta.padded_w) {
            full
        } else {
            full.crop(self.meta.height, self.meta.width)
        })
    }
}

/// Pad an image buffer to `ph×pw` with bin −1 (counts nowhere).
fn pad_image(img: &BinnedImage, ph: usize, pw: usize) -> Vec<i32> {
    let mut out = vec![-1i32; ph * pw];
    for r in 0..img.h {
        out[r * pw..r * pw + img.w].copy_from_slice(&img.data[r * img.w..(r + 1) * img.w]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_image_layout() {
        let img = BinnedImage::new(2, 3, 4, vec![1, 2, 3, 0, 2, 1]);
        let p = pad_image(&img, 3, 4);
        assert_eq!(p, vec![1, 2, 3, -1, 0, 2, 1, -1, -1, -1, -1, -1]);
    }
}
