//! Device pool — the multi-GPU substitute (§4.6, Fig. 18).
//!
//! The paper's superserver runs 4 GTX 480s; the CPU picks bin-group
//! tasks off a queue and dispatches each to whichever GPU is free.
//! Here every worker thread owns its own PJRT CPU client and executor
//! cache (one CUDA context per device, in CUDA terms) and pulls jobs
//! from a shared queue — the same pull-based scheme, which also
//! "handles the imbalanced computation capability of heterogeneous
//! systems" exactly as the paper notes: faster workers simply pull more
//! tasks.
//!
//! Bin grouping trick: all bin-group jobs reuse ONE lowered artifact
//! with `group` bins.  A job for bins `[offset, offset+group)` shifts
//! the image values by `-offset` before execution; values falling
//! outside `[0, group)` count in no bin, so the artifact computes
//! exactly the requested plane slice.  This is how the paper tiles the
//! 3-D tensor along the bin direction without recompiling per group.
//!
//! Fault handling (DESIGN.md §8): a [`DevicePolicy`] gives each device
//! attempt bounded retries with exponential backoff, and a worker whose
//! device path fails `demote_after` consecutive jobs is *demoted* — it
//! stops attempting the device and serves every job on its CPU
//! [`ScanEngine`] (a flapping device should not pay a failed dispatch
//! per job).  With `redemption_ttl` set, a demoted worker retries the
//! device once the TTL elapses; without it, demotion is permanent for
//! the pool's lifetime.  All transitions are counted in
//! [`DevicePoolStats`].

use crate::fault::{FaultAction, FaultInjector, FaultSite};
use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::client::HistogramExecutor;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One bin-group job against a shared frame.
#[derive(Clone)]
pub struct Job {
    pub job_id: usize,
    /// Artifact to run (must be a strategy artifact of `group` bins).
    pub artifact: String,
    /// First bin of this group.
    pub bin_offset: usize,
    /// Bins in this group (what the CPU fallback computes when the
    /// artifact cannot; must equal the artifact's bin count).
    pub group: usize,
    /// Shared input frame (values are FULL-range bin indices).
    pub image: Arc<BinnedImage>,
}

/// Result of one job.
pub struct JobOutput {
    pub job_id: usize,
    pub bin_offset: usize,
    pub worker: usize,
    /// Partial tensor: planes for bins `[bin_offset, bin_offset+group)`.
    pub partial: IntegralHistogram,
    pub kernel_time: Duration,
}

/// Per-pool execution policy: device retry, CPU fallback, and the
/// consecutive-failure demotion ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DevicePolicy {
    /// Serve device-path failures on a per-worker CPU [`ScanEngine`]
    /// (bit-identical output).  Demotion requires this — a demoted
    /// worker with no fallback would only manufacture errors.
    pub cpu_fallback: bool,
    /// Device attempts per job before falling back / erroring.
    /// `1` = no retry (the original behaviour).
    pub exec_attempts: usize,
    /// Backoff before device attempt `k+1` is `backoff << k`.
    pub backoff: Duration,
    /// Consecutive device-path job failures after which a worker stops
    /// attempting the device at all.
    pub demote_after: usize,
    /// If set, a demoted worker re-tries the device after this long
    /// ("redemption"); `None` = demotion is permanent.
    pub redemption_ttl: Option<Duration>,
}

impl Default for DevicePolicy {
    fn default() -> DevicePolicy {
        DevicePolicy {
            cpu_fallback: false,
            exec_attempts: 1,
            backoff: Duration::from_millis(5),
            demote_after: 3,
            redemption_ttl: None,
        }
    }
}

/// Snapshot of pool-wide fault/fallback counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DevicePoolStats {
    /// Jobs served by the device path.
    pub device_jobs: usize,
    /// Jobs served by the CPU fallback engine.
    pub cpu_jobs: usize,
    /// Failed device attempts (each retry that fails counts).
    pub exec_failures: usize,
    /// Device attempts beyond the first within a single job.
    pub exec_retries: usize,
    /// Workers demoted to CPU-only service.
    pub demotions: usize,
    /// Demoted workers that re-tried the device after `redemption_ttl`.
    pub redemptions: usize,
}

#[derive(Default)]
struct PoolShared {
    device_jobs: AtomicUsize,
    cpu_jobs: AtomicUsize,
    exec_failures: AtomicUsize,
    exec_retries: AtomicUsize,
    demotions: AtomicUsize,
    redemptions: AtomicUsize,
}

/// A pool of `n` PJRT workers pulling from a shared job queue.
pub struct DevicePool {
    tx: Option<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Result<JobOutput>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
    shared: Arc<PoolShared>,
}

impl DevicePool {
    /// Spawn `workers` threads; each compiles artifacts lazily from
    /// `manifest` on first use and caches the executable.
    pub fn new(manifest: Arc<ArtifactManifest>, workers: usize) -> DevicePool {
        Self::with_cpu_fallback(manifest, workers, false)
    }

    /// Like [`Self::new`], but workers that cannot compile a job's
    /// artifact (no backend / no artifact in the offline build) serve
    /// the job on a per-worker CPU [`ScanEngine`] instead — same bin
    /// grouping, bit-identical output.  This keeps the §4.6 queue
    /// runnable offline as the serial-frame baseline `benches/shard.rs`
    /// compares the interleaved shard path against.
    pub fn with_cpu_fallback(
        manifest: Arc<ArtifactManifest>,
        workers: usize,
        cpu_fallback: bool,
    ) -> DevicePool {
        Self::with_policy(manifest, workers, DevicePolicy { cpu_fallback, ..Default::default() })
    }

    /// Full-control constructor: retry/demotion policy per
    /// [`DevicePolicy`], plus an optional [`FaultInjector`] whose
    /// [`FaultSite::Compile`] decisions are consulted on every device
    /// attempt (an injected `Error` fails the attempt like a real one).
    pub fn with_policy(
        manifest: Arc<ArtifactManifest>,
        workers: usize,
        policy: DevicePolicy,
    ) -> DevicePool {
        Self::build(manifest, workers, policy, None)
    }

    pub fn with_faults(
        manifest: Arc<ArtifactManifest>,
        workers: usize,
        policy: DevicePolicy,
        faults: Arc<FaultInjector>,
    ) -> DevicePool {
        Self::build(manifest, workers, policy, Some(faults))
    }

    fn build(
        manifest: Arc<ArtifactManifest>,
        workers: usize,
        policy: DevicePolicy,
        faults: Option<Arc<FaultInjector>>,
    ) -> DevicePool {
        assert!(workers >= 1, "need at least one worker");
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (out_tx, out_rx) = mpsc::channel();
        let shared = Arc::new(PoolShared::default());
        let mut handles = Vec::with_capacity(workers);
        for worker_id in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let out_tx = out_tx.clone();
            let manifest = Arc::clone(&manifest);
            let shared = Arc::clone(&shared);
            let faults = faults.clone();
            handles.push(std::thread::spawn(move || {
                let mut cache: HashMap<String, HistogramExecutor> = HashMap::new();
                // Lazy per-worker fallback engine (one "device context"
                // per worker, like the executor cache above).
                let mut engine: Option<ScanEngine> = None;
                // Demotion state: `None` = on the device path;
                // `Some(None)` = demoted permanently; `Some(Some(t))` =
                // demoted until `t` (redemption).
                let mut demoted_until: Option<Option<Instant>> = None;
                let mut consecutive_failures = 0usize;
                loop {
                    // Pull the next task (the Fig. 18 task queue).  A
                    // poisoned queue lock is recovered: the receiver is
                    // valid at every instruction boundary, and one
                    // panicking worker must not idle the whole pool.
                    let job = match lock_recover(&job_rx).recv() {
                        Ok(j) => j,
                        Err(_) => break, // queue closed: drain and exit
                    };
                    if let Some(Some(t)) = demoted_until {
                        if Instant::now() >= t {
                            // TTL elapsed: give the device one fresh run.
                            demoted_until = None;
                            consecutive_failures = 0;
                            shared.redemptions.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let mut out = if demoted_until.is_none() {
                        let r = run_job_with_retry(
                            &manifest,
                            &mut cache,
                            worker_id,
                            &job,
                            &policy,
                            faults.as_deref(),
                            &shared,
                        );
                        match r {
                            Ok(o) => {
                                consecutive_failures = 0;
                                shared.device_jobs.fetch_add(1, Ordering::Relaxed);
                                Ok(o)
                            }
                            Err(e) => {
                                consecutive_failures += 1;
                                if policy.cpu_fallback
                                    && consecutive_failures >= policy.demote_after.max(1)
                                {
                                    shared.demotions.fetch_add(1, Ordering::Relaxed);
                                    demoted_until = Some(
                                        policy.redemption_ttl.map(|ttl| Instant::now() + ttl),
                                    );
                                }
                                Err(e)
                            }
                        }
                    } else {
                        Err(anyhow!("worker {worker_id} demoted to CPU"))
                    };
                    if out.is_err() && policy.cpu_fallback {
                        let eng = engine.get_or_insert_with(|| ScanEngine::new(1));
                        out = run_job_cpu(eng, worker_id, &job);
                        if out.is_ok() {
                            shared.cpu_jobs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    if out_tx.send(out).is_err() {
                        break; // pool dropped
                    }
                }
            }));
        }
        DevicePool { tx: Some(job_tx), rx: out_rx, handles, workers, shared }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Pool-wide fault/fallback counters.
    pub fn stats(&self) -> DevicePoolStats {
        DevicePoolStats {
            device_jobs: self.shared.device_jobs.load(Ordering::Relaxed),
            cpu_jobs: self.shared.cpu_jobs.load(Ordering::Relaxed),
            exec_failures: self.shared.exec_failures.load(Ordering::Relaxed),
            exec_retries: self.shared.exec_retries.load(Ordering::Relaxed),
            demotions: self.shared.demotions.load(Ordering::Relaxed),
            redemptions: self.shared.redemptions.load(Ordering::Relaxed),
        }
    }

    /// Enqueue a job (non-blocking).
    pub fn submit(&self, job: Job) -> Result<()> {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .map_err(|_| anyhow::anyhow!("all workers exited"))
    }

    /// Receive the next completed job (blocking).
    pub fn recv(&self) -> Result<JobOutput> {
        self.rx.recv().context("worker pool hung up")?
    }

    /// Compute a full integral histogram by splitting `total_bins` into
    /// groups of `group` bins and fanning them across the pool.  Returns
    /// the assembled tensor plus the per-job kernel times.
    pub fn compute_grouped(
        &self,
        artifact: &str,
        image: &Arc<BinnedImage>,
        total_bins: usize,
        group: usize,
    ) -> Result<(IntegralHistogram, Vec<Duration>)> {
        assert!(group >= 1 && total_bins % group == 0, "bins must split into equal groups");
        let n_jobs = total_bins / group;
        for j in 0..n_jobs {
            self.submit(Job {
                job_id: j,
                artifact: artifact.to_string(),
                bin_offset: j * group,
                group,
                image: Arc::clone(image),
            })?;
        }
        let mut full = IntegralHistogram::zeros(total_bins, image.h, image.w);
        let plane = image.h * image.w;
        let mut times = Vec::with_capacity(n_jobs);
        for _ in 0..n_jobs {
            let out = self.recv()?;
            let dst = out.bin_offset * plane;
            full.data[dst..dst + out.partial.data.len()].copy_from_slice(&out.partial.data);
            times.push(out.kernel_time);
        }
        Ok((full, times))
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Shift values so a group's bins land in `[0, group)`; out-of-group
/// values count nowhere (bin −1).
fn shifted_group_image(image: &BinnedImage, bin_offset: usize, group: usize) -> BinnedImage {
    let shifted = if bin_offset == 0 {
        image.clone()
    } else {
        let off = bin_offset as i32;
        BinnedImage {
            h: image.h,
            w: image.w,
            bins: group,
            data: image.data.iter().map(|&v| if v >= off { v - off } else { -1 }).collect(),
        }
    };
    BinnedImage { bins: group, ..shifted }
}

/// Device path with [`DevicePolicy`] retry: up to `exec_attempts`
/// tries, exponential backoff between them, every failed attempt
/// counted in the pool stats.
fn run_job_with_retry(
    manifest: &ArtifactManifest,
    cache: &mut HashMap<String, HistogramExecutor>,
    worker: usize,
    job: &Job,
    policy: &DevicePolicy,
    faults: Option<&FaultInjector>,
    shared: &PoolShared,
) -> Result<JobOutput> {
    let attempts = policy.exec_attempts.max(1);
    let mut last_err = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            shared.exec_retries.fetch_add(1, Ordering::Relaxed);
            let pause = policy.backoff.saturating_mul(1u32 << (attempt - 1).min(16));
            if !pause.is_zero() {
                std::thread::sleep(pause);
            }
        }
        let injected = faults
            .is_some_and(|fi| matches!(fi.decide(FaultSite::Compile), Some(FaultAction::Error)));
        let r = if injected {
            Err(anyhow!("injected executor failure on worker {worker}"))
        } else {
            run_job(manifest, cache, worker, job)
        };
        match r {
            Ok(o) => return Ok(o),
            Err(e) => {
                shared.exec_failures.fetch_add(1, Ordering::Relaxed);
                last_err = Some(e);
            }
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow!("job {} failed", job.job_id)))
}

fn run_job(
    manifest: &ArtifactManifest,
    cache: &mut HashMap<String, HistogramExecutor>,
    worker: usize,
    job: &Job,
) -> Result<JobOutput> {
    if !cache.contains_key(&job.artifact) {
        let meta = manifest
            .find_named(&job.artifact)
            .with_context(|| format!("artifact '{}' not in manifest", job.artifact))?;
        cache.insert(job.artifact.clone(), HistogramExecutor::compile(manifest, meta)?);
    }
    let exe = &cache[&job.artifact];
    let group = exe.meta().bins;
    let shifted = shifted_group_image(&job.image, job.bin_offset, group);
    let (partial, kernel_time) = exe.compute_timed(&shifted)?;
    Ok(JobOutput { job_id: job.job_id, bin_offset: job.bin_offset, worker, partial, kernel_time })
}

/// CPU-substrate job execution: the same bin grouping on a per-worker
/// [`ScanEngine`] — the whole-frame serial baseline path when no
/// backend/artifact exists (DESIGN.md §4).
fn run_job_cpu(engine: &mut ScanEngine, worker: usize, job: &Job) -> Result<JobOutput> {
    let shifted = shifted_group_image(&job.image, job.bin_offset, job.group);
    let t0 = Instant::now();
    let partial = engine.compute(&shifted);
    let kernel_time = t0.elapsed();
    Ok(JobOutput { job_id: job.job_id, bin_offset: job.bin_offset, worker, partial, kernel_time })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn empty_manifest() -> Arc<ArtifactManifest> {
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    fn tiny_image() -> Arc<BinnedImage> {
        Arc::new(BinnedImage { h: 2, w: 2, bins: 4, data: vec![0, 1, 2, 3] })
    }

    fn job(id: usize, image: &Arc<BinnedImage>) -> Job {
        Job {
            job_id: id,
            artifact: "missing_artifact".into(),
            bin_offset: 0,
            group: 4,
            image: Arc::clone(image),
        }
    }

    /// Offline, the device path fails every job (artifact is not in the
    /// manifest); after `demote_after` consecutive failures the worker
    /// must stop attempting the device — observable because
    /// `exec_failures` freezes while `cpu_jobs` keeps growing.
    #[test]
    fn worker_demotes_after_consecutive_device_failures() {
        let policy = DevicePolicy {
            cpu_fallback: true,
            exec_attempts: 1,
            backoff: Duration::ZERO,
            demote_after: 2,
            redemption_ttl: None,
        };
        let pool = DevicePool::with_policy(empty_manifest(), 1, policy);
        let image = tiny_image();
        for i in 0..5 {
            pool.submit(job(i, &image)).unwrap();
        }
        for _ in 0..5 {
            pool.recv().expect("cpu fallback must serve every job");
        }
        let st = pool.stats();
        assert_eq!(st.cpu_jobs, 5, "all jobs served on CPU");
        assert_eq!(st.device_jobs, 0);
        assert_eq!(st.exec_failures, 2, "device attempts stop at demotion");
        assert_eq!(st.demotions, 1);
        assert_eq!(st.redemptions, 0, "no TTL, demotion is permanent");
        pool.shutdown();
    }

    /// With a zero redemption TTL every job re-tries the device once
    /// more, fails again, and re-demotes: failures track jobs 1:1.
    #[test]
    fn redemption_ttl_retries_the_device() {
        let policy = DevicePolicy {
            cpu_fallback: true,
            exec_attempts: 1,
            backoff: Duration::ZERO,
            demote_after: 1,
            redemption_ttl: Some(Duration::ZERO),
        };
        let pool = DevicePool::with_policy(empty_manifest(), 1, policy);
        let image = tiny_image();
        for i in 0..3 {
            pool.submit(job(i, &image)).unwrap();
        }
        for _ in 0..3 {
            pool.recv().expect("cpu fallback must serve every job");
        }
        let st = pool.stats();
        assert_eq!(st.cpu_jobs, 3);
        assert_eq!(st.exec_failures, 3, "every job re-tried the device after redemption");
        assert_eq!(st.demotions, 3);
        assert_eq!(st.redemptions, 2, "jobs 2 and 3 redeemed the demotion first");
        pool.shutdown();
    }

    /// Retry policy: each job burns `exec_attempts` device tries before
    /// falling back.
    #[test]
    fn exec_attempts_are_consumed_per_job() {
        let policy = DevicePolicy {
            cpu_fallback: true,
            exec_attempts: 3,
            backoff: Duration::ZERO,
            demote_after: usize::MAX,
            redemption_ttl: None,
        };
        let pool = DevicePool::with_policy(empty_manifest(), 1, policy);
        let image = tiny_image();
        pool.submit(job(0, &image)).unwrap();
        pool.recv().expect("cpu fallback serves the job");
        let st = pool.stats();
        assert_eq!(st.exec_failures, 3);
        assert_eq!(st.exec_retries, 2);
        assert_eq!(st.cpu_jobs, 1);
        pool.shutdown();
    }
}
