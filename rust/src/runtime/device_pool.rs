//! Device pool — the multi-GPU substitute (§4.6, Fig. 18).
//!
//! The paper's superserver runs 4 GTX 480s; the CPU picks bin-group
//! tasks off a queue and dispatches each to whichever GPU is free.
//! Here every worker thread owns its own PJRT CPU client and executor
//! cache (one CUDA context per device, in CUDA terms) and pulls jobs
//! from a shared queue — the same pull-based scheme, which also
//! "handles the imbalanced computation capability of heterogeneous
//! systems" exactly as the paper notes: faster workers simply pull more
//! tasks.
//!
//! Bin grouping trick: all bin-group jobs reuse ONE lowered artifact
//! with `group` bins.  A job for bins `[offset, offset+group)` shifts
//! the image values by `-offset` before execution; values falling
//! outside `[0, group)` count in no bin, so the artifact computes
//! exactly the requested plane slice.  This is how the paper tiles the
//! 3-D tensor along the bin direction without recompiling per group.

use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::client::HistogramExecutor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One bin-group job against a shared frame.
#[derive(Clone)]
pub struct Job {
    pub job_id: usize,
    /// Artifact to run (must be a strategy artifact of `group` bins).
    pub artifact: String,
    /// First bin of this group.
    pub bin_offset: usize,
    /// Bins in this group (what the CPU fallback computes when the
    /// artifact cannot; must equal the artifact's bin count).
    pub group: usize,
    /// Shared input frame (values are FULL-range bin indices).
    pub image: Arc<BinnedImage>,
}

/// Result of one job.
pub struct JobOutput {
    pub job_id: usize,
    pub bin_offset: usize,
    pub worker: usize,
    /// Partial tensor: planes for bins `[bin_offset, bin_offset+group)`.
    pub partial: IntegralHistogram,
    pub kernel_time: Duration,
}

/// A pool of `n` PJRT workers pulling from a shared job queue.
pub struct DevicePool {
    tx: Option<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Result<JobOutput>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl DevicePool {
    /// Spawn `workers` threads; each compiles artifacts lazily from
    /// `manifest` on first use and caches the executable.
    pub fn new(manifest: Arc<ArtifactManifest>, workers: usize) -> DevicePool {
        Self::with_cpu_fallback(manifest, workers, false)
    }

    /// Like [`Self::new`], but workers that cannot compile a job's
    /// artifact (no backend / no artifact in the offline build) serve
    /// the job on a per-worker CPU [`ScanEngine`] instead — same bin
    /// grouping, bit-identical output.  This keeps the §4.6 queue
    /// runnable offline as the serial-frame baseline `benches/shard.rs`
    /// compares the interleaved shard path against.
    pub fn with_cpu_fallback(
        manifest: Arc<ArtifactManifest>,
        workers: usize,
        cpu_fallback: bool,
    ) -> DevicePool {
        assert!(workers >= 1, "need at least one worker");
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (out_tx, out_rx) = mpsc::channel();
        let mut handles = Vec::with_capacity(workers);
        for worker_id in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let out_tx = out_tx.clone();
            let manifest = Arc::clone(&manifest);
            handles.push(std::thread::spawn(move || {
                let mut cache: HashMap<String, HistogramExecutor> = HashMap::new();
                // Lazy per-worker fallback engine (one "device context"
                // per worker, like the executor cache above).
                let mut engine: Option<ScanEngine> = None;
                loop {
                    // Pull the next task (the Fig. 18 task queue).
                    let job = match job_rx.lock().expect("queue lock").recv() {
                        Ok(j) => j,
                        Err(_) => break, // queue closed: drain and exit
                    };
                    let mut out = run_job(&manifest, &mut cache, worker_id, &job);
                    if out.is_err() && cpu_fallback {
                        let eng = engine.get_or_insert_with(|| ScanEngine::new(1));
                        out = run_job_cpu(eng, worker_id, &job);
                    }
                    if out_tx.send(out).is_err() {
                        break; // pool dropped
                    }
                }
            }));
        }
        DevicePool { tx: Some(job_tx), rx: out_rx, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue a job (non-blocking).
    pub fn submit(&self, job: Job) -> Result<()> {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .map_err(|_| anyhow::anyhow!("all workers exited"))
    }

    /// Receive the next completed job (blocking).
    pub fn recv(&self) -> Result<JobOutput> {
        self.rx.recv().context("worker pool hung up")?
    }

    /// Compute a full integral histogram by splitting `total_bins` into
    /// groups of `group` bins and fanning them across the pool.  Returns
    /// the assembled tensor plus the per-job kernel times.
    pub fn compute_grouped(
        &self,
        artifact: &str,
        image: &Arc<BinnedImage>,
        total_bins: usize,
        group: usize,
    ) -> Result<(IntegralHistogram, Vec<Duration>)> {
        assert!(group >= 1 && total_bins % group == 0, "bins must split into equal groups");
        let n_jobs = total_bins / group;
        for j in 0..n_jobs {
            self.submit(Job {
                job_id: j,
                artifact: artifact.to_string(),
                bin_offset: j * group,
                group,
                image: Arc::clone(image),
            })?;
        }
        let mut full = IntegralHistogram::zeros(total_bins, image.h, image.w);
        let plane = image.h * image.w;
        let mut times = Vec::with_capacity(n_jobs);
        for _ in 0..n_jobs {
            let out = self.recv()?;
            let dst = out.bin_offset * plane;
            full.data[dst..dst + out.partial.data.len()].copy_from_slice(&out.partial.data);
            times.push(out.kernel_time);
        }
        Ok((full, times))
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Shift values so a group's bins land in `[0, group)`; out-of-group
/// values count nowhere (bin −1).
fn shifted_group_image(image: &BinnedImage, bin_offset: usize, group: usize) -> BinnedImage {
    let shifted = if bin_offset == 0 {
        image.clone()
    } else {
        let off = bin_offset as i32;
        BinnedImage {
            h: image.h,
            w: image.w,
            bins: group,
            data: image.data.iter().map(|&v| if v >= off { v - off } else { -1 }).collect(),
        }
    };
    BinnedImage { bins: group, ..shifted }
}

fn run_job(
    manifest: &ArtifactManifest,
    cache: &mut HashMap<String, HistogramExecutor>,
    worker: usize,
    job: &Job,
) -> Result<JobOutput> {
    if !cache.contains_key(&job.artifact) {
        let meta = manifest
            .find_named(&job.artifact)
            .with_context(|| format!("artifact '{}' not in manifest", job.artifact))?;
        cache.insert(job.artifact.clone(), HistogramExecutor::compile(manifest, meta)?);
    }
    let exe = &cache[&job.artifact];
    let group = exe.meta().bins;
    let shifted = shifted_group_image(&job.image, job.bin_offset, group);
    let (partial, kernel_time) = exe.compute_timed(&shifted)?;
    Ok(JobOutput { job_id: job.job_id, bin_offset: job.bin_offset, worker, partial, kernel_time })
}

/// CPU-substrate job execution: the same bin grouping on a per-worker
/// [`ScanEngine`] — the whole-frame serial baseline path when no
/// backend/artifact exists (DESIGN.md §4).
fn run_job_cpu(engine: &mut ScanEngine, worker: usize, job: &Job) -> Result<JobOutput> {
    let shifted = shifted_group_image(&job.image, job.bin_offset, job.group);
    let t0 = Instant::now();
    let partial = engine.compute(&shifted);
    let kernel_time = t0.elapsed();
    Ok(JobOutput { job_id: job.job_id, bin_offset: job.bin_offset, worker, partial, kernel_time })
}
