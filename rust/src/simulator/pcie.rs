//! PCI-Express transfer-time model.
//!
//! `t(bytes) = α + bytes / β` — fixed DMA setup latency plus sustained
//! bandwidth.  The α/β constants per card are calibrated so the model
//! reproduces the paper's measured transfer times (e.g. Fig. 11: a
//! 512×512×32 tensor ≈ 32 MB moves in ~2.9 ms on the Titan X's PCIe-3
//! x16 ≈ 11.5 GB/s effective).  The model also answers the paper's two
//! structural questions:
//!
//! * is a configuration compute-bound or transfer-bound (§4.3)?
//! * what frame rate does dual-buffering yield, where transfers of
//!   frame i overlap the kernel of frame i+1 (Fig. 14)?

use std::time::Duration;

/// GPU cards used in the paper's evaluation (§4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Card {
    /// GeForce GTX Titan X (Maxwell, PCIe 3.0 x16).
    TitanX,
    /// Tesla K40c (Kepler, PCIe 3.0 x16).
    K40c,
    /// Tesla C2070 (Fermi, PCIe 2.0 x16).
    C2070,
    /// GeForce GTX 480 (Fermi, PCIe 2.0 x16).
    Gtx480,
}

impl Card {
    pub const ALL: [Card; 4] = [Card::TitanX, Card::K40c, Card::C2070, Card::Gtx480];

    pub fn name(self) -> &'static str {
        match self {
            Card::TitanX => "GTX Titan X",
            Card::K40c => "Tesla K40c",
            Card::C2070 => "Tesla C2070",
            Card::Gtx480 => "GTX 480",
        }
    }
}

/// Linear transfer-time model for one direction of the PCIe link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcieModel {
    /// Fixed per-transfer latency (DMA setup, driver), seconds.
    pub alpha_s: f64,
    /// Sustained bandwidth, bytes/second.
    pub beta_bps: f64,
}

impl PcieModel {
    /// Calibrated model per card generation.  Effective (not theoretical)
    /// bandwidths: PCIe-3 x16 ≈ 11.5 GB/s, PCIe-2 x16 ≈ 5.8 GB/s.
    pub fn for_card(card: Card) -> PcieModel {
        match card {
            Card::TitanX => PcieModel { alpha_s: 8e-6, beta_bps: 11.5e9 },
            Card::K40c => PcieModel { alpha_s: 10e-6, beta_bps: 10.5e9 },
            Card::C2070 => PcieModel { alpha_s: 12e-6, beta_bps: 5.8e9 },
            Card::Gtx480 => PcieModel { alpha_s: 12e-6, beta_bps: 5.6e9 },
        }
    }

    /// Transfer time for `bytes` in one direction.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(self.alpha_s + bytes as f64 / self.beta_bps)
    }

    /// H2D time for the image upload of an `h×w` i32 image.
    pub fn image_upload(&self, h: usize, w: usize) -> Duration {
        self.transfer_time(h * w * 4)
    }

    /// D2H time for the `b×h×w` f32 integral histogram download — the
    /// dominant transfer (the tensor is `bins×` larger than the image).
    pub fn tensor_download(&self, bins: usize, h: usize, w: usize) -> Duration {
        self.transfer_time(bins * h * w * 4)
    }
}

/// Whether a configuration is bound by kernel compute or by transfers
/// (§4.3), and the frame rate each regime implies with dual-buffering
/// (Fig. 14: rate = 1 / max(kernel, transfer)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameRateModel {
    pub kernel: Duration,
    pub transfer: Duration,
}

impl FrameRateModel {
    pub fn new(kernel: Duration, transfer: Duration) -> Self {
        FrameRateModel { kernel, transfer }
    }

    /// From a card model plus measured kernel time: transfer = image up
    /// + tensor down for one frame.
    pub fn for_frame(
        model: &PcieModel,
        kernel: Duration,
        bins: usize,
        h: usize,
        w: usize,
    ) -> Self {
        let transfer = model.image_upload(h, w) + model.tensor_download(bins, h, w);
        FrameRateModel { kernel, transfer }
    }

    pub fn is_transfer_bound(&self) -> bool {
        self.transfer > self.kernel
    }

    /// Frames/second with dual-buffering (compute/copy fully overlapped).
    pub fn fps_dual_buffered(&self) -> f64 {
        1.0 / self.kernel.max(self.transfer).as_secs_f64()
    }

    /// Frames/second without overlap (serial copy → kernel → copy).
    pub fn fps_serial(&self) -> f64 {
        1.0 / (self.kernel + self.transfer).as_secs_f64()
    }

    /// The dual-buffering speedup factor (→ 2.0 when kernel ≈ transfer,
    /// → 1.0 when one side dominates — exactly the Fig. 13 trend).
    pub fn dual_buffer_speedup(&self) -> f64 {
        self.fps_dual_buffered() / self.fps_serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_affine() {
        let m = PcieModel { alpha_s: 1e-5, beta_bps: 1e9 };
        let t0 = m.transfer_time(0).as_secs_f64();
        let t1 = m.transfer_time(1_000_000).as_secs_f64();
        assert!((t0 - 1e-5).abs() < 1e-12);
        assert!((t1 - (1e-5 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn titanx_512_tensor_close_to_paper() {
        // 512×512×32 f32 = 32 MiB; Titan X effective ~11.5 GB/s → ~2.9 ms.
        let m = PcieModel::for_card(Card::TitanX);
        let t = m.tensor_download(32, 512, 512).as_secs_f64() * 1e3;
        assert!((2.0..4.0).contains(&t), "got {t} ms");
    }

    #[test]
    fn fermi_slower_than_maxwell() {
        let a = PcieModel::for_card(Card::TitanX).tensor_download(32, 512, 512);
        let b = PcieModel::for_card(Card::Gtx480).tensor_download(32, 512, 512);
        assert!(b > a);
    }

    #[test]
    fn transfer_bound_classification() {
        let frm = FrameRateModel::new(Duration::from_millis(2), Duration::from_millis(3));
        assert!(frm.is_transfer_bound());
        let frm2 = FrameRateModel::new(Duration::from_millis(5), Duration::from_millis(3));
        assert!(!frm2.is_transfer_bound());
    }

    #[test]
    fn dual_buffer_speedup_peaks_at_balance() {
        // kernel == transfer → 2× (the Fig. 13 16-bin case)
        let bal = FrameRateModel::new(Duration::from_millis(4), Duration::from_millis(4));
        assert!((bal.dual_buffer_speedup() - 2.0).abs() < 1e-9);
        // transfer-dominated → little gain (the Fig. 13 128-bin case)
        let skew = FrameRateModel::new(Duration::from_millis(1), Duration::from_millis(10));
        assert!(skew.dual_buffer_speedup() < 1.2);
    }

    #[test]
    fn fps_monotone_in_time() {
        let fast = FrameRateModel::new(Duration::from_millis(2), Duration::from_millis(2));
        let slow = FrameRateModel::new(Duration::from_millis(8), Duration::from_millis(2));
        assert!(fast.fps_dual_buffered() > slow.fps_dual_buffered());
    }

    #[test]
    fn card_table_complete() {
        for c in Card::ALL {
            let m = PcieModel::for_card(c);
            assert!(m.alpha_s > 0.0 && m.beta_bps > 1e9, "{}", c.name());
        }
    }
}
