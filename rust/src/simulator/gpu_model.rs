//! Kernel-launch overhead and occupancy models.
//!
//! Two pieces of the paper's analysis depend on GPU execution mechanics
//! that a CPU-PJRT substrate cannot observe directly:
//!
//! 1. **Launch overhead** (§3.3): CW-B issues `b·h + b + b·w` tiny kernel
//!    launches; at ~5 µs each this alone explains its 30×+ deficit.  The
//!    figure drivers add `launch_overhead(strategy)` to the measured
//!    kernel time so the CW-B bar lands where the paper's does.
//! 2. **Occupancy** (§4.2.1, Fig. 9): the CUDA-occupancy-calculator
//!    arithmetic — how many thread blocks fit an SM given threads,
//!    registers and shared memory per block — reproduced so the Fig. 9
//!    occupancy-vs-block-size series can be regenerated.

use crate::histogram::types::Strategy;
use crate::simulator::pcie::Card;
use std::time::Duration;

/// Per-launch overhead of a CUDA kernel (driver + queueing), a widely
/// measured ~5 µs on the Kepler/Maxwell generation.
pub const LAUNCH_OVERHEAD: Duration = Duration::from_micros(5);

/// Sustained global-memory bandwidth per card (bytes/second, effective
/// ≈ 80% of the datasheet number).  With [`Strategy::tensor_passes`]
/// this yields the §3.5 kernel-time lower bound the shard planner uses
/// to cost a plan before running it: `kernel ≈ passes × bytes / bw`.
pub fn device_mem_bandwidth(card: Card) -> f64 {
    match card {
        Card::TitanX => 270e9, // 336 GB/s datasheet
        Card::K40c => 230e9,   // 288 GB/s
        Card::C2070 => 115e9,  // 144 GB/s
        Card::Gtx480 => 142e9, // 177 GB/s
    }
}

/// The §3.5 bandwidth-bound throughput prior in **output elements per
/// second**: each f32 element of the `b×h×w` tensor moves
/// `tensor_passes × 4` bytes through device memory, so
/// `elements/s = bw / (passes × 4)`.  This is the cold-start seed for
/// [`crate::tune::Calibrator`] before any measurement exists.
pub fn kernel_throughput_prior(card: Card, strategy: Strategy) -> f64 {
    device_mem_bandwidth(card) / (strategy.tensor_passes() as f64 * 4.0)
}

/// Total launch overhead for a strategy on an `h×w`, `bins`-bin frame.
pub fn launch_overhead(strategy: Strategy, h: usize, w: usize, bins: usize, tile: usize) -> Duration {
    LAUNCH_OVERHEAD * strategy.kernel_launches(h, w, bins, tile) as u32
}

/// Static resources of one streaming multiprocessor (Tesla K40c, the
/// card used for the Fig. 9 tuning experiment).
#[derive(Debug, Clone, Copy)]
pub struct SmResources {
    pub max_threads: usize,
    pub max_blocks: usize,
    pub shared_mem_bytes: usize,
    pub registers: usize,
    pub warp_size: usize,
}

impl SmResources {
    /// Kepler GK110b SMX (K40c).
    pub fn kepler_smx() -> SmResources {
        SmResources {
            max_threads: 2048,
            max_blocks: 16,
            shared_mem_bytes: 48 * 1024,
            registers: 65536,
            warp_size: 32,
        }
    }

    /// Maxwell SMM (Titan X).
    pub fn maxwell_smm() -> SmResources {
        SmResources {
            max_threads: 2048,
            max_blocks: 32,
            shared_mem_bytes: 96 * 1024,
            registers: 65536,
            warp_size: 32,
        }
    }
}

/// Resource demand of one thread block of a kernel.
#[derive(Debug, Clone, Copy)]
pub struct BlockDemand {
    pub threads: usize,
    pub shared_mem_bytes: usize,
    pub registers_per_thread: usize,
}

impl BlockDemand {
    /// The WF-TiS kernel with a given block size and tile edge: shared
    /// memory holds the f32 tile plus a carry column.
    pub fn wf_tis(threads: usize, tile: usize) -> BlockDemand {
        BlockDemand {
            threads,
            shared_mem_bytes: (tile * tile + tile) * 4,
            registers_per_thread: 24,
        }
    }
}

/// CUDA-occupancy-calculator arithmetic: blocks resident per SM and the
/// resulting occupancy fraction (active warps / max warps).
pub fn occupancy(sm: SmResources, block: BlockDemand) -> (usize, f64) {
    if block.threads == 0 || block.threads > sm.max_threads {
        return (0, 0.0);
    }
    let by_threads = sm.max_threads / block.threads;
    let by_blocks = sm.max_blocks;
    let by_shmem = if block.shared_mem_bytes == 0 {
        usize::MAX
    } else {
        sm.shared_mem_bytes / block.shared_mem_bytes
    };
    let by_regs = if block.registers_per_thread == 0 {
        usize::MAX
    } else {
        sm.registers / (block.registers_per_thread * block.threads)
    };
    let resident = by_threads.min(by_blocks).min(by_shmem).min(by_regs);
    let warps = (resident * block.threads).div_ceil(sm.warp_size);
    let max_warps = sm.max_threads / sm.warp_size;
    (resident, (warps.min(max_warps)) as f64 / max_warps as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cwb_overhead_dominates() {
        // 512×512×32: 33 824 launches × 5 µs ≈ 169 ms of pure overhead —
        // the §3.3 "too many kernel invocations" effect.
        let o = launch_overhead(Strategy::CwB, 512, 512, 32, 64);
        assert!(o.as_millis() > 100, "got {o:?}");
        let w = launch_overhead(Strategy::WfTis, 512, 512, 32, 64);
        assert!(w.as_micros() < 200);
    }

    #[test]
    fn occupancy_full_at_512_threads() {
        // Fig. 9: both 512- and 1024-thread configs show 100% occupancy.
        let sm = SmResources::kepler_smx();
        let (_, occ512) = occupancy(sm, BlockDemand { threads: 512, shared_mem_bytes: 8 * 1024, registers_per_thread: 24 });
        let (_, occ1024) = occupancy(sm, BlockDemand { threads: 1024, shared_mem_bytes: 8 * 1024, registers_per_thread: 24 });
        assert_eq!(occ512, 1.0);
        assert_eq!(occ1024, 1.0);
    }

    #[test]
    fn occupancy_limited_by_shared_memory() {
        let sm = SmResources::kepler_smx();
        // a block demanding all 48 KB of shared memory → 1 resident block
        let (resident, occ) = occupancy(sm, BlockDemand { threads: 128, shared_mem_bytes: 48 * 1024, registers_per_thread: 16 });
        assert_eq!(resident, 1);
        assert!(occ < 0.1);
    }

    #[test]
    fn occupancy_zero_for_oversized_block() {
        let sm = SmResources::kepler_smx();
        let (r, o) = occupancy(sm, BlockDemand { threads: 4096, shared_mem_bytes: 0, registers_per_thread: 0 });
        assert_eq!((r, o), (0, 0.0));
    }

    #[test]
    fn wf_tis_block_demand_tile64() {
        let d = BlockDemand::wf_tis(512, 64);
        assert_eq!(d.shared_mem_bytes, (64 * 64 + 64) * 4);
        // 64×64 tile fits the Kepler SMX at least twice
        let (resident, _) = occupancy(SmResources::kepler_smx(), d);
        assert!(resident >= 2);
    }

    #[test]
    fn throughput_prior_tracks_passes_and_bandwidth() {
        // WF-TiS reads+writes the tensor once each (2 passes) → bw/8.
        let p = kernel_throughput_prior(Card::Gtx480, Strategy::WfTis);
        assert_eq!(p, device_mem_bandwidth(Card::Gtx480) / 8.0);
        // More passes → strictly lower prior, on every card.
        for c in Card::ALL {
            assert!(
                kernel_throughput_prior(c, Strategy::CwB)
                    < kernel_throughput_prior(c, Strategy::WfTis),
                "{}",
                c.name()
            );
        }
    }

    #[test]
    fn bandwidth_table_ordering() {
        // Maxwell > Kepler > Fermi, all positive.
        assert!(device_mem_bandwidth(Card::TitanX) > device_mem_bandwidth(Card::K40c));
        assert!(device_mem_bandwidth(Card::K40c) > device_mem_bandwidth(Card::Gtx480));
        for c in Card::ALL {
            assert!(device_mem_bandwidth(c) > 1e10, "{}", c.name());
        }
    }

    #[test]
    fn maxwell_has_more_shared_memory() {
        let d = BlockDemand::wf_tis(256, 64);
        let (rk, _) = occupancy(SmResources::kepler_smx(), d);
        let (rm, _) = occupancy(SmResources::maxwell_smm(), d);
        assert!(rm >= rk);
    }
}
