//! Hardware substitution models (see DESIGN.md §4).
//!
//! This environment has no NVIDIA GPU, so the two hardware-bound
//! quantities in the paper's evaluation are modeled explicitly:
//!
//! * [`pcie`] — CPU↔GPU transfer times over PCI-Express, a calibrated
//!   latency + bandwidth model per card generation.  Used for the
//!   compute-bound vs transfer-bound analysis (Figs. 11, 13, 15) and the
//!   dual-buffering overlap accounting (Fig. 14).
//! * [`gpu_model`] — kernel-launch overhead and occupancy models: the
//!   per-launch cost that buries CW-B (§3.3) and the occupancy
//!   calculator driving the Fig. 9 tuning discussion.

pub mod gpu_model;
pub mod pcie;
