//! `inthist` CLI — the Layer-3 coordinator entry point.
//!
//! Subcommands:
//! * `info`      — platform + artifact inventory.
//! * `compute`   — one frame through one strategy, print timings.
//! * `pipeline`  — stream synthetic (or PGM-directory) video through the
//!   dual-buffered pipeline and report the frame rate.
//! * `large`     — large-image multi-device bin task queue run.
//! * `figures`   — regenerate a paper figure (fig7…fig20, eq4, all).
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — the offline
//! build has no clap; see `inthist <cmd> --help`.

use anyhow::{anyhow, bail, Context, Result};
use inthist::coordinator::pipeline::{Pipeline, PipelineConfig, TransferModel};
use inthist::coordinator::router::{Engine, EngineConfig};
use inthist::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
use inthist::figures;
use inthist::histogram::types::Strategy;
use inthist::runtime::artifact::ArtifactManifest;
use inthist::simulator::pcie::{Card, PcieModel};
use inthist::video::pgm::PgmDirSource;
use inthist::video::source::FrameSource;
use inthist::video::synth::SyntheticVideo;
use std::collections::HashMap;
use std::sync::Arc;

/// Parsed `--key value` flags plus positional args.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if key == "help" {
                    flags.insert("help".into(), "true".into());
                    i += 1;
                    continue;
                }
                let val = argv.get(i + 1).ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    fn wants_help(&self) -> bool {
        self.get("help").is_some()
    }
}

const USAGE: &str = "\
inthist — integral histograms for real-time video analytics

USAGE: inthist <command> [flags]

COMMANDS:
  info                          platform + artifact inventory
  compute  [--strategy wf_tis] [--size 512] [--bins 32]
                                one frame, print kernel/transfer times
  pipeline [--frames 50] [--bins 32] [--size 512] [--lanes 2]
           [--card titanx] [--scale S] [--pgm-dir DIR]
                                dual-buffered streaming run
  large    [--bins 128] [--workers 4] [--group 8] [--size 512]
                                multi-device bin task queue
  figures  <fig7|fig8|fig9|fig10|fig11|fig13|fig15|fig16|fig17|fig19|fig20|eq4|all>
                                regenerate a paper figure
GLOBAL FLAGS:
  --artifacts DIR               artifact directory (default: artifacts)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    if args.wants_help() {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts_dir = args.str_or("artifacts", "artifacts").to_string();
    match cmd {
        "info" => cmd_info(&artifacts_dir),
        "compute" => cmd_compute(&artifacts_dir, &args),
        "pipeline" => cmd_pipeline(&artifacts_dir, &args),
        "large" => cmd_large(&artifacts_dir, &args),
        "figures" => cmd_figures(&artifacts_dir, &args),
        "help" | "-h" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_info(dir: &str) -> Result<()> {
    let manifest = ArtifactManifest::load(dir)?;
    let client = xla::PjRtClient::cpu()?;
    println!(
        "platform: {} ({} devices)",
        client.platform_name(),
        client.device_count()
    );
    println!("artifact profile: {}", manifest.profile);
    println!("{:<36} {:>10} {:>6} {:>6} {:>12}", "artifact", "size", "bins", "tile", "tensor MB");
    for a in &manifest.artifacts {
        println!(
            "{:<36} {:>10} {:>6} {:>6} {:>12.1}",
            a.name,
            format!("{}x{}", a.width, a.height),
            a.bins,
            a.tile,
            a.tensor_bytes() as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_compute(dir: &str, args: &Args) -> Result<()> {
    let size = args.usize("size", 512)?;
    let bins = args.usize("bins", 32)?;
    let strategy: Strategy = args
        .str_or("strategy", "wf_tis")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let mut config = EngineConfig::default();
    config.bins = bins;
    config.strategy = strategy;
    let mut engine = Engine::new(Arc::new(ArtifactManifest::load(dir)?), config);
    let video = SyntheticVideo::new(size, size, 4, 7);
    let frame = video.frame(0);
    let (ih, kernel) = engine.compute_frame_timed(&frame)?;
    let model = PcieModel::for_card(Card::TitanX);
    let transfer = model.image_upload(size, size) + model.tensor_download(bins, size, size);
    println!("strategy        : {strategy}");
    println!("image           : {size}x{size}, {bins} bins");
    println!("tensor          : {:.1} MB", ih.nbytes() as f64 / 1e6);
    println!("kernel time     : {:.3} ms", kernel.as_secs_f64() * 1e3);
    println!("transfer (model): {:.3} ms (Titan X PCIe)", transfer.as_secs_f64() * 1e3);
    println!(
        "bound by        : {}",
        if transfer > kernel { "data transfer" } else { "kernel compute" }
    );
    let corner: f32 = (0..bins).map(|b| ih.at(b, size - 1, size - 1)).sum();
    println!("checksum        : corner mass {corner} (expect {})", size * size);
    Ok(())
}

fn parse_card(name: &str) -> Result<Card> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "titanx" | "titan-x" => Card::TitanX,
        "k40c" | "k40" => Card::K40c,
        "c2070" => Card::C2070,
        "gtx480" | "480" => Card::Gtx480,
        other => bail!("unknown card '{other}' (titanx|k40c|c2070|gtx480)"),
    })
}

fn cmd_pipeline(dir: &str, args: &Args) -> Result<()> {
    let frames = args.usize("frames", 50)?;
    let bins = args.usize("bins", 32)?;
    let size = args.usize("size", 512)?;
    let lanes = args.usize("lanes", 2)?;
    let manifest = Arc::new(ArtifactManifest::load(dir)?);
    let source: Box<dyn FrameSource> = match args.get("pgm-dir") {
        Some(d) => Box::new(PgmDirSource::open(std::path::Path::new(d))?),
        None => Box::new(SyntheticVideo::new(size, size, 4, 7).take_frames(frames)),
    };
    let (h, w) = source.dims();
    let meta = manifest
        .find_strategy(Strategy::WfTis, h, w, bins)
        .ok_or_else(|| anyhow!("no wf_tis artifact for {h}x{w} bins={bins}"))?;
    let mut config = PipelineConfig::new(meta.name.clone(), bins).lanes(lanes);
    if let Some(card) = args.get("card") {
        let scale: f64 = args.str_or("scale", "1.0").parse().context("--scale expects float")?;
        config = config.transfer(TransferModel::Simulated {
            model: PcieModel::for_card(parse_card(card)?),
            scale,
        });
    }
    let report = Pipeline::new(manifest, config).run(source)?;
    let t = &report.throughput;
    println!("frames          : {}", t.frames);
    println!("lanes           : {}", report.lanes);
    println!("wall time       : {:.3} s", t.wall.as_secs_f64());
    println!("frame rate      : {:.2} fr/sec", t.fps());
    println!("mean latency    : {:.3} ms", t.mean_latency().as_secs_f64() * 1e3);
    println!(
        "stage totals    : read {:.1} ms | h2d {:.1} ms | kernel {:.1} ms | d2h {:.1} ms",
        t.stage_total(|s| s.read).as_secs_f64() * 1e3,
        t.stage_total(|s| s.h2d).as_secs_f64() * 1e3,
        t.stage_total(|s| s.kernel).as_secs_f64() * 1e3,
        t.stage_total(|s| s.d2h).as_secs_f64() * 1e3,
    );
    println!("overlap speedup : {:.2}x vs serial estimate", t.overlap_speedup());
    println!("queue high-water: {:?}", report.queue_high_water);
    Ok(())
}

fn cmd_large(dir: &str, args: &Args) -> Result<()> {
    let bins = args.usize("bins", 128)?;
    let workers = args.usize("workers", 4)?;
    let group = args.usize("group", 8)?;
    let size = args.usize("size", 512)?;
    let manifest = Arc::new(ArtifactManifest::load(dir)?);
    let meta = manifest
        .artifacts
        .iter()
        .find(|a| a.bins == group && a.height == size && a.width == size)
        .ok_or_else(|| anyhow!("no {group}-bin artifact for {size}x{size}"))?
        .clone();
    let queue = BinTaskQueue::new(
        Arc::clone(&manifest),
        TaskQueueConfig { workers, group, artifact: meta.name, cpu_fallback: true },
    )?;
    let video = SyntheticVideo::new(size, size, 4, 7);
    let image = Arc::new(video.frame(0).binned(bins));
    let (ih, report) = queue.compute(&image, bins)?;
    println!("image           : {size}x{size}, {bins} bins in {} tasks of {group}", report.tasks);
    println!("workers         : {workers}");
    println!("tensor          : {:.1} MB", ih.nbytes() as f64 / 1e6);
    println!("wall time       : {:.3} s ({:.2} fr/sec)", report.wall.as_secs_f64(), report.fps());
    println!(
        "serial estimate : {:.3} s → pool efficiency {:.0}%",
        report.serial_kernel_time().as_secs_f64(),
        report.efficiency(workers) * 100.0
    );
    println!("tasks per worker: {:?}", report.per_worker);
    let corner: f32 = (0..bins).map(|b| ih.at(b, size - 1, size - 1)).sum();
    println!("checksum        : corner mass {corner} (expect {})", size * size);
    queue.shutdown();
    Ok(())
}

fn cmd_figures(dir: &str, args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("figures needs an id (fig7…fig20, eq4, all)"))?;
    figures::run(dir, which, args.usize("reps", 5)?)
}
