//! Deterministic, seeded fault injection for chaos testing.
//!
//! The supervision machinery in this crate (shard retry, worker
//! replacement, spill checksums, compile/device retry, server shedding)
//! only earns trust if it can be exercised on demand.  This module
//! provides a [`FaultInjector`] that components consult at **named
//! sites** ([`FaultSite`]); the injector answers with an action to
//! simulate — panic, spurious error, slow worker, or corrupted spill
//! bytes — or `None`.
//!
//! Two properties make the resulting chaos runs reproducible:
//!
//! 1. **Interleaving independence.** The decision for the *n*-th
//!    occurrence at a site is a pure splitmix64 hash of
//!    `(seed, site, n)`; the only shared state is a per-site atomic
//!    occurrence counter.  Whichever thread reaches the site n-th gets
//!    the n-th decision, so the *multiset* of injected faults per site
//!    is identical across runs regardless of scheduling.
//! 2. **Bounded schedules.** `max_per_site` caps injections per site so
//!    a chaos test reaches a fault-free steady state and can assert
//!    bit-identical recovery on trailing traffic.
//!
//! The whole module compiles to inert stubs unless the crate is built
//! with `--features fault-injection`: [`FaultInjector::decide`] becomes
//! an inlined `None`, so release hot paths carry no branches, counters
//! or RNG state.  Components therefore hold an
//! `Option<Arc<FaultInjector>>` unconditionally and the compiler folds
//! the probe away in production builds.

use std::time::Duration;

/// Named injection sites.  Each maps to exactly one probe in the code:
/// adding a site here without wiring a probe is a dead schedule entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// `shard::executor` worker, once per compute attempt.
    ShardCompute,
    /// `shard::store::TensorStore::write_rows`, after checksumming.
    SpillWrite,
    /// Disk reads back into the runtime: `TensorStore::read_rows`
    /// (after the read) and the `runtime::artifact` manifest load.
    SpillRead,
    /// `runtime::compile_cache` compile attempt.
    Compile,
    /// `proc::supervisor` shard dispatch: fire → the supervisor
    /// SIGKILLs the target child process (abort/OOM simulation — the
    /// failure mode `catch_unwind` cannot contain).
    WorkerAbort,
}

/// Number of distinct [`FaultSite`] values (array-indexed counters).
pub const FAULT_SITES: usize = 5;

impl FaultSite {
    /// Stable dense index for counter arrays and hashing.
    pub fn index(self) -> usize {
        match self {
            FaultSite::ShardCompute => 0,
            FaultSite::SpillWrite => 1,
            FaultSite::SpillRead => 2,
            FaultSite::Compile => 3,
            FaultSite::WorkerAbort => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::ShardCompute => "shard_compute",
            FaultSite::SpillWrite => "spill_write",
            FaultSite::SpillRead => "spill_read",
            FaultSite::Compile => "compile",
            FaultSite::WorkerAbort => "worker_abort",
        }
    }
}

/// What a probe should simulate when the injector fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic inside the supervised region (`ShardCompute` only).
    Panic,
    /// Fail the attempt with a spurious error (`ShardCompute`, `Compile`).
    Error,
    /// Sleep this long, then proceed normally — a slow worker.
    Delay(Duration),
    /// Flip bytes in the buffer at hand (`SpillWrite`, `SpillRead`).
    Corrupt,
    /// Persist only a truncated prefix of the buffer (`SpillWrite`) —
    /// the classic torn/short disk write a power cut leaves behind.
    ShortWrite,
    /// Kill the worker *process* (`WorkerAbort`) — SIGKILL, not a
    /// catchable panic; exercises the proc supervisor's respawn ladder.
    Abort,
}

/// Per-site probabilities of a seeded fault schedule.
///
/// Probabilities are evaluated per occurrence; for `ShardCompute` the
/// panic/error/delay probabilities partition one uniform draw, so their
/// sum must be ≤ 1.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// P(panic) per shard compute attempt.
    pub shard_panic: f64,
    /// P(spurious error) per shard compute attempt.
    pub shard_error: f64,
    /// P(slow worker) per shard compute attempt.
    pub shard_delay: f64,
    /// Sleep applied when a delay fires.
    pub delay: Duration,
    /// P(corrupt bytes reaching disk) per `write_rows` call.
    pub spill_corrupt_write: f64,
    /// P(short/torn write — only a prefix reaches disk) per
    /// `write_rows` call.  Partitions one uniform draw with
    /// `spill_corrupt_write`, so their sum must be ≤ 1.
    pub spill_short_write: f64,
    /// P(corrupt bytes after a read) per `read_rows` call.
    pub spill_corrupt_read: f64,
    /// P(spurious failure) per compile attempt.
    pub compile_error: f64,
    /// P(child process SIGKILL) per proc-supervisor shard dispatch.
    pub worker_abort: f64,
    /// Cap on injections per site; 0 means unbounded.
    pub max_per_site: usize,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            shard_panic: 0.0,
            shard_error: 0.0,
            shard_delay: 0.0,
            delay: Duration::from_millis(1),
            spill_corrupt_write: 0.0,
            spill_short_write: 0.0,
            spill_corrupt_read: 0.0,
            compile_error: 0.0,
            worker_abort: 0.0,
            max_per_site: 0,
        }
    }
}

/// Counter snapshot of everything an injector has done so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Probe evaluations per site (fired or not).
    pub occurrences: [usize; FAULT_SITES],
    /// Faults actually injected per site.
    pub injected: [usize; FAULT_SITES],
    pub panics: usize,
    pub errors: usize,
    pub delays: usize,
    pub corrupt_writes: usize,
    pub short_writes: usize,
    pub corrupt_reads: usize,
    pub compile_errors: usize,
    pub worker_aborts: usize,
}

impl FaultStats {
    /// Total faults injected across all sites.
    pub fn total_injected(&self) -> usize {
        self.injected.iter().sum()
    }
}

/// splitmix64 finalizer — the same mix `util::prng` seeds with, reused
/// here as a stateless hash so decisions need no per-thread RNG.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Uniform f64 in [0, 1) for occurrence `n` at `site` under `seed`.
/// Pure: the chaos harness (and its Python prevalidation twin) replay
/// the exact schedule from the same inputs.
pub fn fault_roll(seed: u64, site: FaultSite, n: u64) -> f64 {
    let h = splitmix64(seed ^ splitmix64(site.index() as u64 ^ n.wrapping_mul(0xA076_1D64_78BD_642F)));
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Deterministically flip one byte of `buf` (position and XOR mask both
/// derived from `salt`).  No-op on an empty buffer.
pub fn corrupt_bytes(buf: &mut [u8], salt: u64) {
    if buf.is_empty() {
        return;
    }
    let h = splitmix64(salt);
    let pos = (h as usize) % buf.len();
    // Guarantee an actual change: XOR with a non-zero mask.
    let mask = ((h >> 32) as u8) | 1;
    buf[pos] ^= mask;
}

#[cfg(feature = "fault-injection")]
mod imp {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Seeded fault source consulted by supervised components.
    #[derive(Debug)]
    pub struct FaultInjector {
        seed: u64,
        spec: FaultSpec,
        occ: [AtomicUsize; FAULT_SITES],
        injected: [AtomicUsize; FAULT_SITES],
        panics: AtomicUsize,
        errors: AtomicUsize,
        delays: AtomicUsize,
        corrupt_writes: AtomicUsize,
        short_writes: AtomicUsize,
        corrupt_reads: AtomicUsize,
        compile_errors: AtomicUsize,
        worker_aborts: AtomicUsize,
    }

    impl FaultInjector {
        pub fn new(seed: u64, spec: FaultSpec) -> Self {
            let sum = spec.shard_panic + spec.shard_error + spec.shard_delay;
            assert!(sum <= 1.0, "shard fault probabilities sum to {sum} > 1");
            let wsum = spec.spill_corrupt_write + spec.spill_short_write;
            assert!(wsum <= 1.0, "spill write fault probabilities sum to {wsum} > 1");
            FaultInjector {
                seed,
                spec,
                occ: Default::default(),
                injected: Default::default(),
                panics: AtomicUsize::new(0),
                errors: AtomicUsize::new(0),
                delays: AtomicUsize::new(0),
                corrupt_writes: AtomicUsize::new(0),
                short_writes: AtomicUsize::new(0),
                corrupt_reads: AtomicUsize::new(0),
                compile_errors: AtomicUsize::new(0),
                worker_aborts: AtomicUsize::new(0),
            }
        }

        /// Whether the chaos build is active (true here).
        pub fn armed(&self) -> bool {
            true
        }

        /// Consult the schedule at `site`.  Returns the action to
        /// simulate, or `None` to proceed normally.
        pub fn decide(&self, site: FaultSite) -> Option<FaultAction> {
            let i = site.index();
            let n = self.occ[i].fetch_add(1, Ordering::Relaxed) as u64;
            let cap = self.spec.max_per_site;
            if cap > 0 && self.injected[i].load(Ordering::Relaxed) >= cap {
                return None;
            }
            let u = fault_roll(self.seed, site, n);
            let action = match site {
                FaultSite::ShardCompute => {
                    if u < self.spec.shard_panic {
                        Some(FaultAction::Panic)
                    } else if u < self.spec.shard_panic + self.spec.shard_error {
                        Some(FaultAction::Error)
                    } else if u < self.spec.shard_panic + self.spec.shard_error + self.spec.shard_delay {
                        Some(FaultAction::Delay(self.spec.delay))
                    } else {
                        None
                    }
                }
                FaultSite::SpillWrite => {
                    if u < self.spec.spill_corrupt_write {
                        Some(FaultAction::Corrupt)
                    } else if u < self.spec.spill_corrupt_write + self.spec.spill_short_write {
                        Some(FaultAction::ShortWrite)
                    } else {
                        None
                    }
                }
                FaultSite::SpillRead => (u < self.spec.spill_corrupt_read).then_some(FaultAction::Corrupt),
                FaultSite::Compile => (u < self.spec.compile_error).then_some(FaultAction::Error),
                FaultSite::WorkerAbort => (u < self.spec.worker_abort).then_some(FaultAction::Abort),
            };
            if let Some(a) = action {
                self.injected[i].fetch_add(1, Ordering::Relaxed);
                match a {
                    FaultAction::Panic => self.panics.fetch_add(1, Ordering::Relaxed),
                    FaultAction::Delay(_) => self.delays.fetch_add(1, Ordering::Relaxed),
                    FaultAction::Error => match site {
                        FaultSite::Compile => self.compile_errors.fetch_add(1, Ordering::Relaxed),
                        _ => self.errors.fetch_add(1, Ordering::Relaxed),
                    },
                    FaultAction::Corrupt => match site {
                        FaultSite::SpillWrite => self.corrupt_writes.fetch_add(1, Ordering::Relaxed),
                        _ => self.corrupt_reads.fetch_add(1, Ordering::Relaxed),
                    },
                    FaultAction::ShortWrite => self.short_writes.fetch_add(1, Ordering::Relaxed),
                    FaultAction::Abort => self.worker_aborts.fetch_add(1, Ordering::Relaxed),
                };
            }
            action
        }

        /// Snapshot of everything injected so far.
        pub fn stats(&self) -> FaultStats {
            let load = |a: &[AtomicUsize; FAULT_SITES]| {
                let mut out = [0usize; FAULT_SITES];
                for (o, v) in out.iter_mut().zip(a.iter()) {
                    *o = v.load(Ordering::Relaxed);
                }
                out
            };
            FaultStats {
                occurrences: load(&self.occ),
                injected: load(&self.injected),
                panics: self.panics.load(Ordering::Relaxed),
                errors: self.errors.load(Ordering::Relaxed),
                delays: self.delays.load(Ordering::Relaxed),
                corrupt_writes: self.corrupt_writes.load(Ordering::Relaxed),
                short_writes: self.short_writes.load(Ordering::Relaxed),
                corrupt_reads: self.corrupt_reads.load(Ordering::Relaxed),
                compile_errors: self.compile_errors.load(Ordering::Relaxed),
                worker_aborts: self.worker_aborts.load(Ordering::Relaxed),
            }
        }
    }
}

#[cfg(not(feature = "fault-injection"))]
mod imp {
    use super::*;

    /// Inert stub: without the `fault-injection` feature every probe is
    /// an inlined `None` and the optimizer removes the branch entirely.
    #[derive(Debug)]
    pub struct FaultInjector;

    impl FaultInjector {
        pub fn new(_seed: u64, _spec: FaultSpec) -> Self {
            FaultInjector
        }

        /// Whether the chaos build is active (false here).
        #[inline(always)]
        pub fn armed(&self) -> bool {
            false
        }

        #[inline(always)]
        pub fn decide(&self, _site: FaultSite) -> Option<FaultAction> {
            None
        }

        pub fn stats(&self) -> FaultStats {
            FaultStats::default()
        }
    }
}

pub use imp::FaultInjector;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roll_is_deterministic_and_uniform() {
        let a: Vec<f64> = (0..64).map(|n| fault_roll(42, FaultSite::ShardCompute, n)).collect();
        let b: Vec<f64> = (0..64).map(|n| fault_roll(42, FaultSite::ShardCompute, n)).collect();
        assert_eq!(a, b);
        assert!(a.iter().all(|&u| (0.0..1.0).contains(&u)));
        // Different sites / seeds decorrelate.
        let c: Vec<f64> = (0..64).map(|n| fault_roll(42, FaultSite::SpillRead, n)).collect();
        assert_ne!(a, c);
        let d: Vec<f64> = (0..64).map(|n| fault_roll(43, FaultSite::ShardCompute, n)).collect();
        assert_ne!(a, d);
    }

    #[test]
    fn corrupt_changes_exactly_one_byte() {
        let orig: Vec<u8> = (0..=255u8).collect();
        let mut buf = orig.clone();
        corrupt_bytes(&mut buf, 7);
        let diffs = orig.iter().zip(&buf).filter(|(a, b)| a != b).count();
        assert_eq!(diffs, 1);
        // Deterministic for the same salt.
        let mut buf2 = orig.clone();
        corrupt_bytes(&mut buf2, 7);
        assert_eq!(buf, buf2);
        corrupt_bytes(&mut [], 3); // no-op, must not panic
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn armed_injector_honors_probabilities_and_cap() {
        let spec = FaultSpec { shard_panic: 1.0, max_per_site: 3, ..FaultSpec::default() };
        let fi = FaultInjector::new(1, spec);
        assert!(fi.armed());
        for _ in 0..3 {
            assert_eq!(fi.decide(FaultSite::ShardCompute), Some(FaultAction::Panic));
        }
        // Cap reached: further probes are clean.
        for _ in 0..10 {
            assert_eq!(fi.decide(FaultSite::ShardCompute), None);
        }
        let st = fi.stats();
        assert_eq!(st.panics, 3);
        assert_eq!(st.injected[FaultSite::ShardCompute.index()], 3);
        assert_eq!(st.occurrences[FaultSite::ShardCompute.index()], 13);
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn short_write_partitions_the_spill_write_draw() {
        // P(short) = 1 with P(corrupt) = 0 → every decision is a short
        // write, counted separately from corruption.
        let spec = FaultSpec { spill_short_write: 1.0, max_per_site: 2, ..FaultSpec::default() };
        let fi = FaultInjector::new(5, spec);
        assert_eq!(fi.decide(FaultSite::SpillWrite), Some(FaultAction::ShortWrite));
        assert_eq!(fi.decide(FaultSite::SpillWrite), Some(FaultAction::ShortWrite));
        assert_eq!(fi.decide(FaultSite::SpillWrite), None, "cap honored");
        let st = fi.stats();
        assert_eq!((st.short_writes, st.corrupt_writes), (2, 0));
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn same_seed_same_schedule() {
        let spec = FaultSpec {
            shard_panic: 0.2,
            shard_error: 0.2,
            shard_delay: 0.1,
            ..FaultSpec::default()
        };
        let a = FaultInjector::new(99, spec);
        let b = FaultInjector::new(99, spec);
        let sa: Vec<_> = (0..256).map(|_| a.decide(FaultSite::ShardCompute)).collect();
        let sb: Vec<_> = (0..256).map(|_| b.decide(FaultSite::ShardCompute)).collect();
        assert_eq!(sa, sb);
        assert!(sa.iter().any(|d| d.is_some()) && sa.iter().any(|d| d.is_none()));
    }

    #[cfg(not(feature = "fault-injection"))]
    #[test]
    fn stub_injector_is_inert() {
        let spec = FaultSpec { shard_panic: 1.0, ..FaultSpec::default() };
        let fi = FaultInjector::new(1, spec);
        assert!(!fi.armed());
        assert_eq!(fi.decide(FaultSite::ShardCompute), None);
        assert_eq!(fi.stats(), FaultStats::default());
    }
}
