//! `ShardPlanner` — partition one integral-histogram request into
//! bin-range (and, when the memory budget demands it, spatial-strip)
//! shards.
//!
//! The paper's §4.6 scale result is a *planning* result: a 64 MB image
//! at 128 bins produces a 32 GB tensor, so the tensor is tiled along
//! the bin axis into group tasks sized to what one device can hold,
//! and Fig. 18 costs the schedule as (per-task kernel time, per-task
//! transfer time) pairs.  This module turns that arithmetic into an
//! explicit plan object:
//!
//! * the **bin axis** is split into equal groups (the paper's 8/16-bin
//!   tasks) sized so one shard's partial tensor fits the per-shard
//!   slice of the caller's memory budget;
//! * when even a single bin plane exceeds that slice (the 64 MB-image
//!   case), shards are additionally split into **row strips** — a
//!   strip's local integral is exact up to a per-column carry that the
//!   [`crate::shard::Reassembler`] adds back, so strips compose
//!   bit-identically for integer-valued counts;
//! * when the frame is small but the executor has idle workers, rows
//!   are split anyway (bounded oversubscription) so shard-level
//!   parallelism does not collapse at low bin counts — the adaptive
//!   splitting argument of "Fast Histograms using Adaptive CUDA
//!   Streams" (PAPERS.md);
//! * every plan can be **costed before it runs** with the same models
//!   the figure drivers use ([`crate::simulator::pcie`] transfer times,
//!   [`crate::simulator::gpu_model`] launch overhead + memory
//!   bandwidth), which is how `examples/multi_gpu_large_image.rs`
//!   prints predicted-vs-measured per-shard columns.
//!
//! The planner is pure (no I/O, no allocation beyond the plan) and
//! deterministic: one request maps to one plan.

use crate::histogram::types::Strategy;
use crate::simulator::gpu_model::{device_mem_bandwidth, launch_overhead};
use crate::simulator::pcie::{Card, PcieModel};
use crate::tune::CostSnapshot;
use std::time::Duration;

/// Policy knobs for the shard planner.
#[derive(Debug, Clone, Copy)]
pub struct ShardPolicy {
    /// Peak resident bytes allowed per in-flight frame on the host —
    /// partial tensors in flight, reorder buffers and carry rows all
    /// count against it.  This is the knob that makes the 32 GB-tensor
    /// configuration runnable on a bounded-memory host.
    pub memory_budget: usize,
    /// Shard executor worker count the plan will run on (sizes the
    /// in-flight share of the budget and the oversubscription target).
    pub workers: usize,
    /// Largest bin group per shard (the paper uses 8/16-bin tasks).
    pub max_group: usize,
    /// Minimum shards per frame; when the bin axis alone yields fewer,
    /// rows are split to reach it (0 ⇒ `workers`).
    pub min_shards: usize,
    /// Card whose PCIe/memory models cost the plan (Fig. 18 uses the
    /// GTX 480 quartet).
    pub card: Card,
}

impl Default for ShardPolicy {
    fn default() -> ShardPolicy {
        ShardPolicy {
            memory_budget: 1 << 30,
            workers: 4,
            max_group: 16,
            min_shards: 0,
            card: Card::Gtx480,
        }
    }
}

/// One shard: a bin range × row strip of the output tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Index in plan (= issue) order; results are tagged with it.
    pub shard_id: usize,
    /// First bin of this shard's range.
    pub bin0: usize,
    /// Bins in this shard's range.
    pub nbins: usize,
    /// First image row of this shard's strip.
    pub row0: usize,
    /// Rows in this shard's strip.
    pub nrows: usize,
}

impl ShardSpec {
    /// Bytes of this shard's partial tensor (`nbins×nrows×w` f32).
    pub fn nbytes(&self, w: usize) -> usize {
        self.nbins * self.nrows * w * 4
    }
}

/// Predicted cost of one shard under the paper's models.
#[derive(Debug, Clone, Copy)]
pub struct ShardCost {
    /// Modeled device kernel time: `tensor_passes` crossings of the
    /// partial tensor at device memory bandwidth, plus §3.3 launch
    /// overhead for the shard's geometry.
    pub kernel: Duration,
    /// Modeled PCIe time: sub-image upload + partial tensor download.
    pub transfer: Duration,
}

/// Aggregate prediction for a whole plan on `workers` devices.
#[derive(Debug, Clone, Copy)]
pub struct PlanCost {
    /// Sum of per-shard kernel times (single-device serial estimate).
    pub serial_kernel: Duration,
    /// Sum of per-shard transfer times (one shared PCIe link).
    pub serial_transfer: Duration,
    /// Makespan estimate with compute spread over `workers` and
    /// transfers overlapped behind it (Fig. 14 overlap argument lifted
    /// to the pool): `max(kernel/workers, transfer)`.
    pub wall: Duration,
}

/// The partition of one `bins×h×w` request into tagged shards.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub bins: usize,
    pub h: usize,
    pub w: usize,
    /// Shards in issue order: bin-major, then row strips top-to-bottom
    /// (the Fig. 2 layout order, so spilled planes stream to disk
    /// near-sequentially).
    pub shards: Vec<ShardSpec>,
    /// Bins per (full) bin group.
    pub group: usize,
    /// Rows per (full) strip — `h` when the row axis is unsplit.
    pub strip_rows: usize,
    /// Whether the full tensor exceeds the memory budget, i.e. the
    /// caller must reassemble into a spill-backed
    /// [`crate::shard::TensorStore`] rather than host RAM.
    pub spill: bool,
    /// The per-shard byte bound the planner solved for.
    pub per_shard_budget: usize,
}

impl ShardPlan {
    /// Bytes of the full `bins×h×w` tensor.
    pub fn tensor_nbytes(&self) -> usize {
        self.bins * self.h * self.w * 4
    }

    /// Largest single shard in bytes.
    pub fn max_shard_nbytes(&self) -> usize {
        self.shards.iter().map(|s| s.nbytes(self.w)).max().unwrap_or(0)
    }

    /// Row strips per bin group.
    pub fn strips_per_group(&self) -> usize {
        self.h.div_ceil(self.strip_rows)
    }

    /// Predict per-shard costs with the §4.6 models for `card`.
    pub fn predict(&self, card: Card) -> Vec<ShardCost> {
        let pcie = PcieModel::for_card(card);
        let bw = device_mem_bandwidth(card);
        let passes = Strategy::WfTis.tensor_passes() as f64;
        self.shards
            .iter()
            .map(|s| {
                let bytes = s.nbytes(self.w) as f64;
                let kernel = Duration::from_secs_f64(passes * bytes / bw)
                    + launch_overhead(Strategy::WfTis, s.nrows, self.w, s.nbins, 64);
                let transfer =
                    pcie.image_upload(s.nrows, self.w) + pcie.tensor_download(s.nbins, s.nrows, self.w);
                ShardCost { kernel, transfer }
            })
            .collect()
    }

    /// Aggregate the per-shard prediction into a makespan estimate.
    pub fn predict_total(&self, card: Card, workers: usize) -> PlanCost {
        aggregate(&self.predict(card), workers)
    }

    /// Predict per-shard costs from a **measured** [`CostSnapshot`]
    /// instead of the paper's static card models: kernel time from the
    /// calibrator's best tile throughput plus one dispatch per shard
    /// (the executor issues each shard as one engine job), transfer
    /// time from measured host-copy bandwidth — plus spill latency +
    /// spill bandwidth for the partial tensor when the plan spills.
    /// Callers should pass a [`CostSnapshot::sanitized`] snapshot.
    pub fn predict_with(&self, snap: &CostSnapshot) -> Vec<ShardCost> {
        let tput = snap.best_throughput();
        self.shards
            .iter()
            .map(|s| {
                let tensor_bytes = s.nbytes(self.w) as f64;
                let elems = (s.nbins * s.nrows * self.w) as f64;
                let kernel = Duration::from_secs_f64(elems / tput + snap.dispatch_overhead_s);
                // Image strip in, partial tensor out, through host copies.
                let mut t = (tensor_bytes + (s.nrows * self.w * 4) as f64) / snap.memcpy_bps;
                if self.spill {
                    t += snap.spill_read_latency_s + tensor_bytes / snap.spill_read_bps;
                }
                ShardCost { kernel, transfer: Duration::from_secs_f64(t) }
            })
            .collect()
    }

    /// [`Self::predict_total`] over the calibrated snapshot.
    pub fn predict_total_with(&self, snap: &CostSnapshot, workers: usize) -> PlanCost {
        aggregate(&self.predict_with(snap), workers)
    }

    /// A reassembly deadline for this plan: the predicted makespan
    /// times `slack` (≥ 1; 4–8 is reasonable — retries and interleaved
    /// neighbors inflate the fault-free estimate), floored at 100 ms so
    /// tiny plans are not starved by scheduler jitter.  Feed it to the
    /// `FrameTicket::reassemble_*_deadline` variants.
    pub fn suggested_deadline(&self, card: Card, workers: usize, slack: f64) -> Duration {
        let wall = self.predict_total(card, workers).wall;
        let scaled = Duration::from_secs_f64(wall.as_secs_f64() * slack.max(1.0));
        scaled.max(Duration::from_millis(100))
    }
}

/// Shared makespan aggregation (Fig. 14 overlap argument lifted to the
/// pool): compute spreads over `workers`, transfers share one link.
fn aggregate(per: &[ShardCost], workers: usize) -> PlanCost {
    let serial_kernel: Duration = per.iter().map(|c| c.kernel).sum();
    let serial_transfer: Duration = per.iter().map(|c| c.transfer).sum();
    let spread = Duration::from_secs_f64(serial_kernel.as_secs_f64() / workers.max(1) as f64);
    PlanCost { serial_kernel, serial_transfer, wall: spread.max(serial_transfer) }
}

/// The planner: policy in, deterministic plan out.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardPlanner {
    pub policy: ShardPolicy,
}

impl ShardPlanner {
    pub fn new(policy: ShardPolicy) -> ShardPlanner {
        ShardPlanner { policy }
    }

    /// Partition a `bins×h×w` request.
    ///
    /// Budget discipline: a frame's resident bytes are `workers` shards
    /// computing, up to `workers` more parked in the executor's bounded
    /// completion channel, a near-FIFO reorder window (≈ `workers`) in
    /// the reassembler, plus carry rows and one strip of commit
    /// scratch.  Sizing each shard to `memory_budget / (4·workers + 4)`
    /// leaves that whole envelope inside `memory_budget`; the
    /// reassembler's peak-resident counter asserts it
    /// (`tests/shard_property.rs`).
    pub fn plan(&self, bins: usize, h: usize, w: usize) -> ShardPlan {
        assert!(bins >= 1 && h >= 1 && w >= 1, "degenerate request");
        let p = self.policy;
        let workers = p.workers.max(1);
        let tensor = bins * h * w * 4;
        let spill = tensor > p.memory_budget;
        let slack = 4 * workers + 4;
        // Never plan below one row of one bin — the indivisible unit.
        let per_shard_budget = (p.memory_budget / slack).max(w * 4);
        let plane = h * w * 4;

        // Bin axis first: the largest group whose partial fits the
        // per-shard budget, capped by policy and by the bin count.
        let by_budget = (per_shard_budget / plane).max(1).min(bins);
        let mut group = p.max_group.max(1).min(by_budget);
        // Row axis: forced when one plane alone busts the budget …
        let mut strip_rows = h;
        if plane > per_shard_budget {
            group = 1;
            strip_rows = (per_shard_budget / (w * 4)).clamp(1, h);
        }
        // … or adaptive, when the bin axis alone leaves workers idle.
        let min_shards = if p.min_shards == 0 { workers } else { p.min_shards };
        let n_groups = bins.div_ceil(group);
        if n_groups * h.div_ceil(strip_rows) < min_shards {
            let want_strips = min_shards.div_ceil(n_groups).min(h);
            strip_rows = strip_rows.min(h.div_ceil(want_strips)).max(1);
        }

        // Issue order: bin-major, strips top-to-bottom within a group
        // (reassembly carries flow downward; spilled planes stream out
        // in Fig. 2 order).
        let mut shards = Vec::with_capacity(bins.div_ceil(group) * h.div_ceil(strip_rows));
        let mut shard_id = 0;
        let mut bin0 = 0;
        while bin0 < bins {
            let nbins = group.min(bins - bin0);
            let mut row0 = 0;
            while row0 < h {
                let nrows = strip_rows.min(h - row0);
                shards.push(ShardSpec { shard_id, bin0, nbins, row0, nrows });
                shard_id += 1;
                row0 += nrows;
            }
            bin0 += nbins;
        }
        ShardPlan { bins, h, w, shards, group, strip_rows, spill, per_shard_budget }
    }

    /// Shard sizing costed with **measured** numbers: enumerate the
    /// executable grouping policies (bin-group sizes, oversubscription
    /// targets), cost each candidate plan with
    /// [`ShardPlan::predict_total_with`] under `snap`, keep the lowest
    /// modeled makespan.
    ///
    /// Two invariants hold under *any* snapshot, adversarial included
    /// (property-tested in `tests/tune_property.rs`):
    ///
    /// * the static [`Self::plan`] is the initial incumbent and only a
    ///   strictly lower cost replaces it — so the calibrated plan never
    ///   model-costs worse than the static one, and with the cold-start
    ///   prior snapshot ties resolve to the paper-constant plan;
    /// * every candidate is produced by [`Self::plan`] under the same
    ///   `memory_budget`, so the budget discipline (per-shard bound,
    ///   exact cover) is structural, not dependent on the snapshot —
    ///   which is first [`CostSnapshot::sanitized`] anyway so degenerate
    ///   measurements cannot poison the cost comparison.
    pub fn plan_calibrated(&self, bins: usize, h: usize, w: usize, snap: &CostSnapshot) -> ShardPlan {
        let snap = snap.sanitized(self.policy.card);
        let workers = self.policy.workers.max(1);
        let mut best = self.plan(bins, h, w);
        let mut best_cost = best.predict_total_with(&snap, workers).wall;
        let mut consider = |policy: ShardPolicy| {
            let cand = ShardPlanner::new(policy).plan(bins, h, w);
            let cost = cand.predict_total_with(&snap, workers).wall;
            if cost < best_cost {
                best = cand;
                best_cost = cost;
            }
        };
        // Bin-group sizes: powers of two up to the policy cap (the
        // paper's 8/16-bin tasks plus the finer splits measured
        // dispatch overhead may or may not justify).
        let mut g = 1usize;
        while g <= self.policy.max_group.max(1) {
            // Oversubscription: 1×, 2×, 4× the worker count.
            for over in [1usize, 2, 4] {
                consider(ShardPolicy {
                    max_group: g,
                    min_shards: workers * over,
                    ..self.policy
                });
            }
            g *= 2;
        }
        best
    }

    /// Per-node calibrated planning — the process-per-NUMA-node step:
    /// each execution node (child process) runs its own `Calibrator`
    /// and reports a [`CostSnapshot`]; this sizes one plan for the
    /// whole fleet and assigns every shard to a node.
    ///
    /// * **Sizing** runs [`Self::plan_calibrated`] with the worker
    ///   count set to the node count and an element-wise mean of the
    ///   sanitized node snapshots (one aggregate machine model — shard
    ///   granularity should reflect fleet-average cost, while *balance*
    ///   reflects per-node differences);
    /// * **Assignment** is LPT greedy weighted by measured node speed:
    ///   shards in descending element-count order, each placed on the
    ///   node whose finish time `(load + weight) / best_throughput` is
    ///   lowest (ties → lowest node index), so a node that calibrated
    ///   2× faster ends up with ≈ 2× the work.
    ///
    /// Deterministic: same snapshots, same `(plan, assignment)`.  The
    /// returned vector maps `shard_id → node index`; an empty snapshot
    /// slice degrades to one prior-model node (everything on node 0).
    pub fn plan_per_node(
        &self,
        bins: usize,
        h: usize,
        w: usize,
        snaps: &[CostSnapshot],
    ) -> (ShardPlan, Vec<usize>) {
        let card = self.policy.card;
        let clean: Vec<CostSnapshot> = if snaps.is_empty() {
            vec![CostSnapshot::static_prior(card)]
        } else {
            snaps.iter().map(|s| s.sanitized(card)).collect()
        };
        let nodes = clean.len();
        // Aggregate fleet model: element-wise mean of the node snapshots.
        let mut agg = clean[0];
        if nodes > 1 {
            let inv = 1.0 / nodes as f64;
            agg.memcpy_bps = clean.iter().map(|s| s.memcpy_bps).sum::<f64>() * inv;
            agg.dispatch_overhead_s =
                clean.iter().map(|s| s.dispatch_overhead_s).sum::<f64>() * inv;
            agg.spill_read_latency_s =
                clean.iter().map(|s| s.spill_read_latency_s).sum::<f64>() * inv;
            agg.spill_read_bps = clean.iter().map(|s| s.spill_read_bps).sum::<f64>() * inv;
            for i in 0..agg.tile_throughput.len() {
                agg.tile_throughput[i] =
                    clean.iter().map(|s| s.tile_throughput[i]).sum::<f64>() * inv;
                agg.tile_throughput_tuned[i] =
                    clean.iter().map(|s| s.tile_throughput_tuned[i]).sum::<f64>() * inv;
            }
            agg.samples = clean.iter().map(|s| s.samples).sum();
        }
        let sizer = ShardPlanner::new(ShardPolicy { workers: nodes, ..self.policy });
        let plan = sizer.plan_calibrated(bins, h, w, &agg);

        // LPT greedy: heaviest shards first onto the node that finishes
        // them earliest at its measured speed.  The speed divisor gets
        // its own defense in depth on top of `sanitized`: a degenerate
        // entry here poisons `(load + weight) / speed` into NaN finish
        // times, and NaN comparisons make *every* `t < best_t` false —
        // the whole frame silently piles onto node 0 and the rest of
        // the fleet idles.  `lpt_speeds` repairs such entries before
        // they reach the loop.
        let speeds = lpt_speeds(&clean.iter().map(|s| s.best_throughput()).collect::<Vec<_>>());
        let mut order: Vec<usize> = (0..plan.shards.len()).collect();
        order.sort_by(|&a, &b| {
            let wa = plan.shards[a].nbins * plan.shards[a].nrows;
            let wb = plan.shards[b].nbins * plan.shards[b].nrows;
            wb.cmp(&wa).then(a.cmp(&b))
        });
        let mut load = vec![0.0f64; nodes];
        let mut assignment = vec![0usize; plan.shards.len()];
        for &i in &order {
            let weight = (plan.shards[i].nbins * plan.shards[i].nrows * w) as f64;
            let mut best_node = 0;
            let mut best_t = f64::INFINITY;
            for (n, &speed) in speeds.iter().enumerate() {
                let t = (load[n] + weight) / speed;
                if t < best_t {
                    best_t = t;
                    best_node = n;
                }
            }
            load[best_node] += weight;
            assignment[i] = best_node;
        }
        (plan, assignment)
    }
}

/// Repair a node-speed vector for LPT assignment: every non-finite or
/// non-positive entry is replaced by the mean of the valid entries —
/// or `1.0` (uniform LPT) when no entry is valid — so hostile
/// calibration can skew the *balance* of an assignment but never
/// produce NaN weights or an assignment that starves every node but
/// index 0.
fn lpt_speeds(raw: &[f64]) -> Vec<f64> {
    let valid: Vec<f64> = raw.iter().copied().filter(|s| s.is_finite() && *s > 0.0).collect();
    let fallback = if valid.is_empty() {
        1.0
    } else {
        valid.iter().sum::<f64>() / valid.len() as f64
    };
    raw.iter()
        .map(|&s| if s.is_finite() && s > 0.0 { s } else { fallback })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planner(budget: usize, workers: usize) -> ShardPlanner {
        ShardPlanner::new(ShardPolicy {
            memory_budget: budget,
            workers,
            ..ShardPolicy::default()
        })
    }

    /// Plans must tile the tensor exactly: every (bin, row) covered
    /// once, ids dense in issue order.
    fn assert_exact_cover(plan: &ShardPlan) {
        let mut cover = vec![0u32; plan.bins * plan.h];
        for (i, s) in plan.shards.iter().enumerate() {
            assert_eq!(s.shard_id, i, "ids must be dense in issue order");
            assert!(s.nbins >= 1 && s.nrows >= 1);
            assert!(s.bin0 + s.nbins <= plan.bins && s.row0 + s.nrows <= plan.h);
            for b in s.bin0..s.bin0 + s.nbins {
                for r in s.row0..s.row0 + s.nrows {
                    cover[b * plan.h + r] += 1;
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1), "every (bin, row) exactly once");
    }

    #[test]
    fn small_request_covers_and_oversubscribes() {
        let plan = planner(1 << 30, 4).plan(8, 64, 64);
        assert_exact_cover(&plan);
        assert!(!plan.spill);
        assert!(plan.shards.len() >= 4, "at least one shard per worker");
    }

    #[test]
    fn bin_groups_respect_budget() {
        // 32 bins × 128×128 plane = 64 KiB/plane; budget 1 MiB over 4
        // workers → per-shard ≤ 1 MiB/20 ≈ 52 KiB → 1-bin row strips.
        let plan = planner(1 << 20, 4).plan(32, 128, 128);
        assert_exact_cover(&plan);
        assert!(plan.max_shard_nbytes() <= plan.per_shard_budget);
    }

    #[test]
    fn oversized_plane_forces_row_strips() {
        // One 256×256 plane = 256 KiB > per-shard slice of a 1 MiB
        // budget → strips.
        let plan = planner(1 << 20, 4).plan(128, 256, 256);
        assert_exact_cover(&plan);
        assert!(plan.spill, "tensor exceeds budget");
        assert_eq!(plan.group, 1);
        assert!(plan.strip_rows < 256);
        assert!(plan.max_shard_nbytes() <= plan.per_shard_budget);
    }

    #[test]
    fn degenerate_budget_still_plans_whole_rows() {
        let plan = planner(16, 2).plan(4, 8, 8);
        assert_exact_cover(&plan);
        assert_eq!(plan.strip_rows, 1, "floor is one row per shard");
    }

    #[test]
    fn uneven_bins_and_rows_tile_exactly() {
        let mut p = planner(1 << 14, 3);
        p.policy.max_group = 4;
        let plan = p.plan(7, 33, 29);
        assert_exact_cover(&plan);
    }

    #[test]
    fn prediction_is_positive_and_scales() {
        let plan = planner(1 << 26, 4).plan(128, 1024, 1024);
        let costs = plan.predict(Card::Gtx480);
        assert_eq!(costs.len(), plan.shards.len());
        assert!(costs.iter().all(|c| c.kernel > Duration::ZERO && c.transfer > Duration::ZERO));
        let total4 = plan.predict_total(Card::Gtx480, 4);
        let total1 = plan.predict_total(Card::Gtx480, 1);
        assert!(total4.wall <= total1.wall, "more workers can't predict slower");
        assert_eq!(total4.serial_kernel, total1.serial_kernel);
    }

    #[test]
    fn suggested_deadline_scales_with_slack_and_floors() {
        let plan = planner(1 << 26, 4).plan(128, 1024, 1024);
        let d1 = plan.suggested_deadline(Card::Gtx480, 4, 1.0);
        let d4 = plan.suggested_deadline(Card::Gtx480, 4, 4.0);
        assert!(d4 >= d1, "more slack can't shorten the deadline");
        assert!(d1 >= plan.predict_total(Card::Gtx480, 4).wall);
        // A tiny plan hits the floor instead of a microsecond deadline.
        let tiny = planner(1 << 20, 2).plan(2, 8, 8);
        assert!(tiny.suggested_deadline(Card::Gtx480, 2, 1.0) >= Duration::from_millis(100));
    }

    #[test]
    fn calibrated_prediction_is_positive_and_aggregates_like_static() {
        let plan = planner(1 << 26, 4).plan(128, 1024, 1024);
        let snap = CostSnapshot::static_prior(Card::Gtx480);
        let per = plan.predict_with(&snap);
        assert_eq!(per.len(), plan.shards.len());
        assert!(per.iter().all(|c| c.kernel > Duration::ZERO && c.transfer > Duration::ZERO));
        let t4 = plan.predict_total_with(&snap, 4);
        let t1 = plan.predict_total_with(&snap, 1);
        assert!(t4.wall <= t1.wall);
        assert_eq!(t4.serial_kernel, t1.serial_kernel);
        // Spilling plans pay the spill terms on top.
        let spilled = planner(1 << 20, 4).plan(128, 256, 256);
        assert!(spilled.spill);
        let c = spilled.predict_total_with(&snap, 4);
        assert!(c.serial_transfer > Duration::ZERO);
    }

    #[test]
    fn calibrated_plan_matches_or_beats_static_in_model_terms() {
        let p = planner(1 << 26, 4);
        let snap = CostSnapshot::static_prior(Card::Gtx480);
        for (bins, h, w) in [(128usize, 1024usize, 1024usize), (8, 64, 64), (32, 512, 512)] {
            let cal = p.plan_calibrated(bins, h, w, &snap);
            let fixed = p.plan(bins, h, w);
            assert!(
                cal.predict_total_with(&snap, 4).wall <= fixed.predict_total_with(&snap, 4).wall,
                "{bins}x{h}x{w}"
            );
            assert!(cal.max_shard_nbytes() <= cal.per_shard_budget.max(w * 4));
        }
    }

    #[test]
    fn adversarial_snapshot_cannot_break_the_calibrated_plan() {
        let p = planner(1 << 20, 4);
        for bad in [f64::NAN, f64::INFINITY, -1.0, 0.0] {
            let mut snap = CostSnapshot::static_prior(Card::Gtx480);
            snap.memcpy_bps = bad;
            snap.tile_throughput = [bad; 4];
            snap.dispatch_overhead_s = bad;
            snap.spill_read_bps = bad;
            let plan = p.plan_calibrated(32, 128, 128, &snap);
            assert!(plan.max_shard_nbytes() <= plan.per_shard_budget);
            assert!(!plan.shards.is_empty());
        }
    }

    #[test]
    fn per_node_assignment_covers_and_balances_identical_nodes() {
        let p = planner(1 << 26, 4);
        let snaps = vec![CostSnapshot::static_prior(Card::Gtx480); 3];
        let (plan, assignment) = p.plan_per_node(32, 256, 256, &snaps);
        assert_exact_cover(&plan);
        assert_eq!(assignment.len(), plan.shards.len());
        assert!(assignment.iter().all(|&n| n < 3));
        // Identical nodes → near-even element loads (LPT bound).
        let mut load = [0usize; 3];
        for (i, s) in plan.shards.iter().enumerate() {
            load[assignment[i]] += s.nbins * s.nrows;
        }
        let (lo, hi) = (*load.iter().min().unwrap(), *load.iter().max().unwrap());
        assert!(load.iter().all(|&l| l > 0), "every node gets work: {load:?}");
        assert!(hi <= 2 * lo.max(1), "balanced within LPT slack: {load:?}");
        // Deterministic: same snapshots, same assignment.
        let (_, again) = p.plan_per_node(32, 256, 256, &snaps);
        assert_eq!(assignment, again);
    }

    #[test]
    fn per_node_assignment_favors_the_faster_node() {
        let p = planner(1 << 26, 4);
        let slow = CostSnapshot::static_prior(Card::Gtx480);
        let mut fast = slow;
        for t in fast.tile_throughput.iter_mut().chain(fast.tile_throughput_tuned.iter_mut()) {
            *t *= 3.0;
        }
        let (plan, assignment) = p.plan_per_node(16, 192, 192, &[slow, fast]);
        let mut load = [0usize; 2];
        for (i, s) in plan.shards.iter().enumerate() {
            load[assignment[i]] += s.nbins * s.nrows;
        }
        assert!(load[1] > load[0], "3x-faster node carries more work: {load:?}");
    }

    /// The placement-weight bugfix, unit half: degenerate speeds are
    /// repaired, not propagated.  NaN/zero/negative/infinite entries
    /// take the mean of the valid ones; an all-degenerate vector
    /// degrades to uniform LPT.
    #[test]
    fn lpt_speeds_repairs_degenerate_entries() {
        let fixed = lpt_speeds(&[2.0, f64::NAN, 6.0, 0.0, -3.0, f64::INFINITY]);
        assert_eq!(fixed, vec![2.0, 4.0, 6.0, 4.0, 4.0, 4.0]);
        assert_eq!(lpt_speeds(&[f64::NAN, 0.0, f64::NEG_INFINITY]), vec![1.0; 3]);
        assert_eq!(lpt_speeds(&[]), Vec::<f64>::new());
        let healthy = lpt_speeds(&[1.0, 2.0, 3.0]);
        assert_eq!(healthy, vec![1.0, 2.0, 3.0], "valid speeds pass through untouched");
    }

    /// The placement-weight bugfix, end-to-end half: adversarial node
    /// snapshots (NaN, ±∞, zero, negative, denormal — including mixed
    /// fleets where only one node is hostile) still yield an exact
    /// cover, in-range node indices, and work on every node when the
    /// plan has at least one shard per node — never NaN weights, never
    /// an all-idle fleet.
    #[test]
    fn per_node_survives_adversarial_snapshots() {
        let p = planner(1 << 20, 4);
        let healthy = CostSnapshot::static_prior(Card::Gtx480);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, 0.0, f64::MIN_POSITIVE] {
            let mut hostile = healthy;
            hostile.memcpy_bps = bad;
            hostile.tile_throughput = [bad; 4];
            hostile.tile_throughput_tuned = [bad; 4];
            hostile.dispatch_overhead_s = bad;
            hostile.spill_read_latency_s = bad;
            hostile.spill_read_bps = bad;
            for snaps in [vec![hostile; 3], vec![hostile, healthy, hostile]] {
                let (plan, assignment) = p.plan_per_node(16, 96, 96, &snaps);
                assert_exact_cover(&plan);
                assert_eq!(assignment.len(), plan.shards.len());
                assert!(assignment.iter().all(|&n| n < snaps.len()), "{bad}: {assignment:?}");
                let mut load = vec![0usize; snaps.len()];
                for (i, s) in plan.shards.iter().enumerate() {
                    load[assignment[i]] += s.nbins * s.nrows;
                }
                assert!(
                    plan.shards.len() < snaps.len() || load.iter().all(|&l| l > 0),
                    "{bad}: no node starves when shards cover the fleet: {load:?}"
                );
                // Deterministic under hostility too.
                let (_, again) = p.plan_per_node(16, 96, 96, &snaps);
                assert_eq!(assignment, again, "{bad}");
            }
        }
    }

    #[test]
    fn per_node_with_no_snapshots_degrades_to_one_prior_node() {
        let p = planner(1 << 26, 4);
        let (plan, assignment) = p.plan_per_node(8, 64, 64, &[]);
        assert_exact_cover(&plan);
        assert!(assignment.iter().all(|&n| n == 0));
    }

    #[test]
    fn paper_scale_configuration_plans_under_bounded_budget() {
        // §4.6 / Fig. 18: 64 MB image (8k×8k) × 128 bins = 32 GB tensor
        // through a 256 MiB host budget.
        let plan = planner(256 << 20, 4).plan(128, 8192, 8192);
        assert!(plan.spill);
        assert!(plan.max_shard_nbytes() <= plan.per_shard_budget);
        assert_exact_cover(&plan);
    }
}
