//! Sharded out-of-core execution — the §4.6 scale path as a subsystem.
//!
//! The paper's headline scale result (Fig. 18) pushes a 32 GB integral
//! histogram tensor — 64 MB image × 128 bins — through four GPUs that
//! individually hold a fraction of it, at 0.73 Hz and 153× over the
//! CPU baseline.  The mechanism is structural, not kernel-level: the
//! tensor is partitioned along the bin axis, partitions stream through
//! whatever device frees up first, and the host reassembles (or
//! discards) partitions as they land.  This module is that mechanism
//! as a composable subsystem over the serving stack:
//!
//! * [`planner::ShardPlanner`] — partitions a request into bin-range ×
//!   row-strip shards under an explicit host memory budget, costed
//!   with the paper's transfer/launch models before anything runs;
//! * [`executor::ShardExecutor`] — one worker set running shards from
//!   *multiple in-flight frames interleaved*, every result tagged
//!   `(frame_id, shard_id)` — retiring the one-job-per-pool and
//!   whole-frame-serialization limits of the PR-2 large-image route;
//! * [`reassemble::Reassembler`] — streams tagged shards, in any
//!   completion order, into a sink: row strips compose through a
//!   per-column carry, bit-identically for count-valued tensors;
//! * [`store::TensorStore`] — the spill-backed sink: completed rows
//!   land on disk in Fig. 2 layout and Eq. 2 box-histogram queries run
//!   against the file in O(bins) corner reads, so the 32 GB
//!   configuration serves region queries from a bounded-memory host.
//!
//! [`crate::coordinator::server::Server`] routes oversized frames here
//! (see `ServerConfig::shard_*`); `examples/out_of_core.rs` and
//! `benches/shard.rs` drive the subsystem directly.

pub mod executor;
pub mod planner;
pub mod reassemble;
pub mod store;

pub use executor::{FrameTicket, ShardExecutor, ShardExecutorConfig, ShardExecutorStats, ShardReport};
pub use planner::{PlanCost, ShardCost, ShardPlan, ShardPlanner, ShardPolicy, ShardSpec};
pub use reassemble::{RamSink, Reassembler, ShardSink};
pub use store::TensorStore;

use crate::histogram::types::IntegralHistogram;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Typed failure of one submitted frame, delivered through its
/// [`FrameTicket`] — the executor's contract is *no hangs*: every
/// submitted frame either reassembles bit-identical to a fault-free
/// run or resolves to exactly one of these within its deadline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardError {
    /// A shard exhausted its compute retries on spurious errors.
    ComputeFailed { frame_id: u64, shard_id: usize, attempts: usize, reason: String },
    /// A shard exhausted its compute retries on worker panics (each
    /// caught by the supervisor; the engine involved is discarded).
    ComputePanicked { frame_id: u64, shard_id: usize, attempts: usize },
    /// The caller-supplied reassembly deadline elapsed first.
    DeadlineExceeded { frame_id: u64, deadline: Duration, completed: usize, expected: usize },
    /// Every worker exited while the frame was still incomplete.
    WorkersGone { frame_id: u64 },
    /// Shard composition itself failed (malformed shard, sink error).
    Reassembly { frame_id: u64, reason: String },
}

impl ShardError {
    pub fn frame_id(&self) -> u64 {
        match self {
            ShardError::ComputeFailed { frame_id, .. }
            | ShardError::ComputePanicked { frame_id, .. }
            | ShardError::DeadlineExceeded { frame_id, .. }
            | ShardError::WorkersGone { frame_id }
            | ShardError::Reassembly { frame_id, .. } => *frame_id,
        }
    }
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::ComputeFailed { frame_id, shard_id, attempts, reason } => write!(
                f,
                "frame {frame_id} shard {shard_id}: compute failed after {attempts} attempts: {reason}"
            ),
            ShardError::ComputePanicked { frame_id, shard_id, attempts } => write!(
                f,
                "frame {frame_id} shard {shard_id}: compute panicked on all {attempts} attempts"
            ),
            ShardError::DeadlineExceeded { frame_id, deadline, completed, expected } => write!(
                f,
                "frame {frame_id}: deadline {deadline:?} exceeded with {completed}/{expected} shards reassembled"
            ),
            ShardError::WorkersGone { frame_id } => {
                write!(f, "frame {frame_id}: all shard workers exited mid-frame")
            }
            ShardError::Reassembly { frame_id, reason } => {
                write!(f, "frame {frame_id}: reassembly failed: {reason}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

/// One shard's output, tagged with its origin — the unit that flows
/// from executor workers to reassembly.
pub struct TaggedShard {
    /// Which in-flight frame this shard belongs to.
    pub frame_id: u64,
    /// Which piece of that frame's plan it is.
    pub spec: ShardSpec,
    /// The shard's *local* integral (`nbins×nrows×w`, carry-free).
    pub partial: IntegralHistogram,
    /// Worker that computed it (utilization accounting).
    pub worker: usize,
    /// Pure compute time of the shard.
    pub kernel_time: Duration,
}

/// A current/peak byte gauge: every buffer a frame holds resident —
/// partial tensors in flight, reorder buffers, carries, scratch — is
/// charged here, so "peak resident ≤ budget" is a counter assertion.
#[derive(Debug, Default)]
pub struct ResidentGauge {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl ResidentGauge {
    pub fn add(&self, bytes: usize) {
        let now = self.cur.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn sub(&self, bytes: usize) {
        self.cur.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Bytes currently resident.
    pub fn current(&self) -> usize {
        self.cur.load(Ordering::Relaxed)
    }

    /// High-water mark.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_tracks_current_and_peak() {
        let g = ResidentGauge::default();
        g.add(100);
        g.add(50);
        assert_eq!(g.current(), 150);
        g.sub(120);
        assert_eq!(g.current(), 30);
        assert_eq!(g.peak(), 150, "peak survives the drain");
        g.add(10);
        assert_eq!(g.peak(), 150);
    }
}
