//! `Reassembler` — streaming, order-tolerant reassembly of tagged
//! shard outputs.
//!
//! Shards arrive tagged `(frame_id, shard_id)` in *completion* order
//! (the executor interleaves frames and workers finish when they
//! finish).  Two facts make streaming reassembly possible without ever
//! holding the full tensor:
//!
//! 1. **Bin ranges are independent** — a bin-group shard lands in its
//!    own planes, so groups commit in any relative order.
//! 2. **Row strips compose by a per-column carry** — a strip's local
//!    integral starts from zero at its top row, and the exact full
//!    value is `local(b, r, c) + H(b, row0−1, c)` (Algorithm 1's
//!    recurrence only couples rows through the previous row).  The
//!    carry row is the last committed row of the strip above, so
//!    strips of one group commit top-to-bottom; an early-arriving
//!    lower strip is parked in a reorder buffer until its predecessor
//!    lands.
//!
//! Committed rows stream into a [`ShardSink`]: host RAM
//! ([`RamSink`]) when the tensor fits, or the spill-backed
//! [`TensorStore`](crate::shard::TensorStore) when it does not.  Every
//! buffered byte (parked shards, carry rows, the commit scratch) is
//! charged to the frame's [`ResidentGauge`](crate::shard::ResidentGauge),
//! so "peak resident tensor bytes ≤ budget" is a counter assertion,
//! not a hope (`tests/shard_property.rs`).

use crate::coordinator::frame_pool::FramePool;
use crate::histogram::types::IntegralHistogram;
use crate::shard::planner::ShardPlan;
use crate::shard::{ResidentGauge, TaggedShard};
use crate::shard::store::TensorStore;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Where carry-corrected rows land.  `bin`/`row0` are absolute tensor
/// coordinates; `rows` is a whole number of `w`-length rows.
pub trait ShardSink {
    fn commit_rows(&mut self, bin: usize, row0: usize, rows: &[f32]) -> Result<()>;
}

/// Sink writing into a caller tensor in RAM.
pub struct RamSink<'a> {
    out: &'a mut IntegralHistogram,
}

impl<'a> RamSink<'a> {
    /// Wrap `out`, resizing its (possibly recycled) storage to
    /// `bins×h×w` without zeroing — every element is committed exactly
    /// once, same discipline as
    /// [`ScanEngine::compute_into`](crate::histogram::engine::ScanEngine::compute_into).
    pub fn new(out: &'a mut IntegralHistogram, bins: usize, h: usize, w: usize) -> RamSink<'a> {
        out.bins = bins;
        out.h = h;
        out.w = w;
        let n = bins * h * w;
        if out.data.len() != n {
            out.data.resize(n, 0.0);
        }
        RamSink { out }
    }
}

impl ShardSink for RamSink<'_> {
    fn commit_rows(&mut self, bin: usize, row0: usize, rows: &[f32]) -> Result<()> {
        let w = self.out.w;
        if bin >= self.out.bins || rows.len() % w != 0 || row0 * w + rows.len() > self.out.h * w {
            return Err(anyhow!("commit outside tensor: bin {bin} row0 {row0} len {}", rows.len()));
        }
        let dst = (bin * self.out.h + row0) * w;
        self.out.data[dst..dst + rows.len()].copy_from_slice(rows);
        Ok(())
    }
}

impl ShardSink for TensorStore {
    fn commit_rows(&mut self, bin: usize, row0: usize, rows: &[f32]) -> Result<()> {
        TensorStore::write_rows(self, bin, row0, rows)
    }
}

/// Per-bin-group progress: the next committable row and the carry row
/// (absolute integral at `next_row − 1`, one `w` vector per bin).
struct GroupState {
    bin0: usize,
    nbins: usize,
    next_row: usize,
    /// `nbins×w` once a non-final strip committed; dropped at group end.
    carry: Vec<f32>,
}

/// Streaming reassembler for one frame's plan.
pub struct Reassembler {
    h: usize,
    w: usize,
    groups: Vec<GroupState>,
    /// Reorder buffer: `(group, row0) → early shard`.
    parked: HashMap<(usize, usize), TaggedShard>,
    /// Commit scratch (one strip of one bin, carry-corrected).
    scratch: Vec<f32>,
    /// Shards accepted so far.
    accepted: usize,
    expected: usize,
    /// Partial-tensor storage recycles here after commit.
    pool: Option<Arc<FramePool>>,
    gauge: Arc<ResidentGauge>,
    /// Bytes currently charged for carries + scratch (so drop can
    /// settle the gauge exactly).
    charged_state: usize,
}

impl Reassembler {
    pub fn new(plan: &ShardPlan, pool: Option<Arc<FramePool>>, gauge: Arc<ResidentGauge>) -> Reassembler {
        let mut groups = Vec::new();
        let mut bin0 = 0;
        while bin0 < plan.bins {
            let nbins = plan.group.min(plan.bins - bin0);
            groups.push(GroupState { bin0, nbins, next_row: 0, carry: Vec::new() });
            bin0 += nbins;
        }
        Reassembler {
            h: plan.h,
            w: plan.w,
            groups,
            parked: HashMap::new(),
            scratch: Vec::new(),
            accepted: 0,
            expected: plan.shards.len(),
            pool,
            gauge,
            charged_state: 0,
        }
    }

    /// All shards accepted and committed.
    pub fn finished(&self) -> bool {
        self.accepted == self.expected
            && self.parked.is_empty()
            && self.groups.iter().all(|g| g.next_row == self.h)
    }

    /// Shards accepted so far — the progress figure a deadline error
    /// reports for an abandoned frame.
    pub fn accepted(&self) -> usize {
        self.accepted
    }

    /// Shards the plan expects in total.
    pub fn expected(&self) -> usize {
        self.expected
    }

    /// Shards parked in the reorder buffer right now.
    pub fn parked(&self) -> usize {
        self.parked.len()
    }

    fn group_index(&self, bin0: usize) -> Result<usize> {
        self.groups
            .iter()
            .position(|g| g.bin0 == bin0)
            .ok_or_else(|| anyhow!("shard bin0 {bin0} matches no planned group"))
    }

    /// Accept one tagged shard, committing it (and any unparked
    /// successors) to `sink` when its predecessors have landed.
    pub fn accept(&mut self, shard: TaggedShard, sink: &mut dyn ShardSink) -> Result<()> {
        let g = self.group_index(shard.spec.bin0)?;
        if shard.spec.nbins != self.groups[g].nbins
            || shard.partial.data.len() < shard.spec.nbins * shard.spec.nrows * self.w
        {
            return Err(anyhow!("shard {:?} does not match its planned group", shard.spec));
        }
        self.accepted += 1;
        if shard.spec.row0 != self.groups[g].next_row {
            if shard.spec.row0 < self.groups[g].next_row
                || self.parked.contains_key(&(g, shard.spec.row0))
            {
                return Err(anyhow!("duplicate commit for rows at {}", shard.spec.row0));
            }
            self.parked.insert((g, shard.spec.row0), shard);
            return Ok(());
        }
        self.commit(g, shard, sink)?;
        // Unpark successors now unblocked.
        while let Some(next) = self.parked.remove(&(g, self.groups[g].next_row)) {
            self.commit(g, next, sink)?;
        }
        Ok(())
    }

    /// Commit one in-order strip: add the group carry column-wise,
    /// stream rows to the sink, refresh the carry from the last row.
    fn commit(&mut self, g: usize, shard: TaggedShard, sink: &mut dyn ShardSink) -> Result<()> {
        let (w, h) = (self.w, self.h);
        let spec = shard.spec;
        let (nrows, nbins) = (spec.nrows, spec.nbins);
        let strip = nrows * w;
        let last_strip = spec.row0 + nrows == h;
        let group = &mut self.groups[g];
        let has_carry = !group.carry.is_empty();
        if !has_carry && !last_strip {
            // First of several strips: allocate (and charge) the carry.
            group.carry.resize(nbins * w, 0.0);
            let bytes = nbins * w * 4;
            self.gauge.add(bytes);
            self.charged_state += bytes;
        }
        if has_carry && self.scratch.len() < strip {
            let grow = (strip - self.scratch.len()) * 4;
            self.scratch.resize(strip, 0.0);
            self.gauge.add(grow);
            self.charged_state += grow;
        }
        let group = &mut self.groups[g];
        for b in 0..nbins {
            let local = &shard.partial.data[b * strip..(b + 1) * strip];
            let rows: &[f32] = if has_carry {
                let carry = &group.carry[b * w..(b + 1) * w];
                for r in 0..nrows {
                    for c in 0..w {
                        self.scratch[r * w + c] = local[r * w + c] + carry[c];
                    }
                }
                &self.scratch[..strip]
            } else {
                local
            };
            sink.commit_rows(spec.bin0 + b, spec.row0, rows)?;
            if !last_strip {
                if group.carry.is_empty() {
                    // has_carry was false but more strips follow; the
                    // allocation above guarantees this is unreachable —
                    // keep the invariant explicit.
                    return Err(anyhow!("carry missing for non-final strip"));
                }
                group.carry[b * w..(b + 1) * w].copy_from_slice(&rows[(nrows - 1) * w..]);
            }
        }
        group.next_row = spec.row0 + nrows;
        if last_strip && !group.carry.is_empty() {
            let bytes = group.carry.len() * 4;
            group.carry = Vec::new();
            self.gauge.sub(bytes);
            self.charged_state -= bytes;
        }
        // Recycle the partial and settle its resident charge (the
        // executor charged it at acquisition).
        let bytes = shard.partial.nbytes();
        if let Some(pool) = &self.pool {
            pool.release(shard.partial);
        }
        self.gauge.sub(bytes);
        Ok(())
    }
}

impl Drop for Reassembler {
    fn drop(&mut self) {
        // Abandoned-frame tolerance: a reassembler dropped mid-frame
        // (deadline miss, typed failure, caller gave up) must leave no
        // dangling charges — parked partials recycle to the pool and
        // every gauge byte settles, so the executor's resident
        // accounting stays exact across failures.
        let mut parked_bytes = 0;
        for (_, s) in self.parked.drain() {
            parked_bytes += s.partial.nbytes();
            if let Some(pool) = &self.pool {
                pool.release(s.partial);
            }
        }
        self.gauge.sub(parked_bytes + self.charged_state);
        self.charged_state = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;
    use crate::shard::planner::{ShardPlanner, ShardPolicy, ShardSpec};
    use crate::util::prng::Xoshiro256;
    use std::time::Duration;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    /// Compute one shard's local partial the way the executor does:
    /// slice rows, shift bins, run the sequential arbiter.
    fn local_partial(img: &BinnedImage, spec: ShardSpec) -> IntegralHistogram {
        let w = img.w;
        let mut data = Vec::with_capacity(spec.nrows * w);
        for r in spec.row0..spec.row0 + spec.nrows {
            for c in 0..w {
                let v = img.at(r, c);
                let v = v - spec.bin0 as i32;
                data.push(if v >= 0 && (v as usize) < spec.nbins { v } else { -1 });
            }
        }
        let sub = BinnedImage::new(spec.nrows, w, spec.nbins, data);
        integral_histogram_seq(&sub)
    }

    fn tagged(img: &BinnedImage, spec: ShardSpec) -> TaggedShard {
        TaggedShard {
            frame_id: 0,
            spec,
            partial: local_partial(img, spec),
            worker: 0,
            kernel_time: Duration::ZERO,
        }
    }

    fn reassemble_in_order(img: &BinnedImage, policy: ShardPolicy, order: &[usize]) -> IntegralHistogram {
        let plan = ShardPlanner::new(policy).plan(img.bins, img.h, img.w);
        let gauge = Arc::new(ResidentGauge::default());
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        {
            let mut reasm = Reassembler::new(&plan, None, Arc::clone(&gauge));
            let mut sink = RamSink::new(&mut out, plan.bins, plan.h, plan.w);
            let ids: Vec<usize> = if order.is_empty() {
                (0..plan.shards.len()).collect()
            } else {
                order.to_vec()
            };
            assert_eq!(ids.len(), plan.shards.len(), "order must be a permutation");
            for &i in &ids {
                let shard = tagged(img, plan.shards[i]);
                gauge.add(shard.partial.nbytes());
                reasm.accept(shard, &mut sink).expect("accept");
            }
            assert!(reasm.finished(), "all shards must commit");
        }
        assert_eq!(gauge.current(), 0, "all charges settled once the reassembler drops");
        out
    }

    #[test]
    fn strips_compose_bit_identically_in_order() {
        let img = random_image(37, 23, 6, 1);
        let policy = ShardPolicy { memory_budget: 8 << 10, workers: 3, ..ShardPolicy::default() };
        let got = reassemble_in_order(&img, policy, &[]);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn out_of_order_arrival_parks_and_composes() {
        let img = random_image(29, 17, 4, 9);
        let policy = ShardPolicy { memory_budget: 4 << 10, workers: 2, ..ShardPolicy::default() };
        let plan = ShardPlanner::new(policy).plan(4, 29, 17);
        assert!(plan.shards.len() >= 4, "want a multi-strip plan");
        // Fully reversed completion order: maximal parking.
        let order: Vec<usize> = (0..plan.shards.len()).rev().collect();
        let got = reassemble_in_order(&img, policy, &order);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn shuffled_arrival_composes() {
        let img = random_image(41, 19, 5, 4);
        let policy = ShardPolicy { memory_budget: 6 << 10, workers: 4, ..ShardPolicy::default() };
        let plan = ShardPlanner::new(policy).plan(5, 41, 19);
        let mut order: Vec<usize> = (0..plan.shards.len()).collect();
        let mut rng = Xoshiro256::new(77);
        for i in (1..order.len()).rev() {
            let j = rng.range(0, i + 1);
            order.swap(i, j);
        }
        let got = reassemble_in_order(&img, policy, &order);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn duplicate_and_alien_shards_are_rejected() {
        let img = random_image(8, 8, 2, 2);
        let policy = ShardPolicy { memory_budget: 1 << 20, workers: 1, min_shards: 1, ..ShardPolicy::default() };
        let plan = ShardPlanner::new(policy).plan(2, 8, 8);
        let gauge = Arc::new(ResidentGauge::default());
        let mut reasm = Reassembler::new(&plan, None, gauge);
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let mut sink = RamSink::new(&mut out, 2, 8, 8);
        let first = plan.shards[0];
        reasm.accept(tagged(&img, first), &mut sink).expect("first commit");
        let dup = tagged(&img, first);
        assert!(reasm.accept(dup, &mut sink).is_err(), "duplicate must be rejected");
        let alien = TaggedShard {
            frame_id: 0,
            spec: ShardSpec { shard_id: 99, bin0: 1, nbins: 7, row0: 0, nrows: 8 },
            partial: IntegralHistogram::zeros(7, 8, 8),
            worker: 0,
            kernel_time: Duration::ZERO,
        };
        assert!(reasm.accept(alien, &mut sink).is_err(), "alien group must be rejected");
    }
}
