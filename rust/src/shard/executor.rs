//! `ShardExecutor` — the shared multi-job scheduler that runs shards
//! from any number of in-flight frames interleaved over one worker
//! set.
//!
//! This retires the two limits the ROADMAP called out in the PR-2
//! serving layer: the `BinTaskQueue` ran **one job per pool** and the
//! `Server` **serialized whole frames** on it (head-of-line blocking —
//! a queued 4k frame stalled every other large request).  Here:
//!
//! * one fixed set of worker threads pulls `(frame_id, shard_id)`
//!   tagged jobs from a single FIFO — shards of different frames
//!   interleave freely, so frame N+1's shards fill the drain tail of
//!   frame N (the idle slots a lone frame leaves when its last shards
//!   occupy fewer workers than exist);
//! * each worker computes on a [`ScanEngine`] checked out of a shared
//!   LIFO stack (warm scratch and parked
//!   [`WorkerPool`](crate::histogram::engine::WorkerPool) reused
//!   across jobs and frames), with a persistent per-thread sub-image
//!   buffer — the steady state allocates no per-shard buffers beyond
//!   the pooled partial tensors;
//! * results stream back through a **bounded** per-frame channel
//!   (capacity ≈ workers), so a slow consumer exerts backpressure on
//!   the workers instead of buffering unboundedly — the discipline
//!   that keeps the out-of-core path inside its memory budget;
//! * the caller holds a [`FrameTicket`] per submitted frame and drives
//!   reassembly (into RAM or a spilled
//!   [`TensorStore`](crate::shard::TensorStore)) on its own thread,
//!   overlapping frame N's reassembly with frame N+1's compute.
//!
//! Ordering note: when one thread holds several tickets it must
//! reassemble them in submission order (jobs leave the FIFO in that
//! order, and the bounded channels are what bound memory); tickets
//! held by different threads — the server's session model — drain
//! independently in any order.

use crate::coordinator::frame_pool::{FramePool, PoolStats};
use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::shard::planner::{ShardPlan, ShardSpec};
use crate::shard::reassemble::{RamSink, Reassembler, ShardSink};
use crate::shard::store::TensorStore;
use crate::shard::{ResidentGauge, TaggedShard};
use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Executor configuration.
#[derive(Debug, Clone, Copy)]
pub struct ShardExecutorConfig {
    /// Worker threads (the paper's device count; Fig. 18 uses 4).
    pub workers: usize,
    /// `ScanEngine` thread budget per shard.  1 by default: shard-level
    /// parallelism comes from the worker set, not from one shard
    /// grabbing every core.
    pub engine_workers: usize,
    /// Completed-shard backpressure depth per frame (0 ⇒ `workers`).
    pub channel_depth: usize,
}

impl Default for ShardExecutorConfig {
    fn default() -> ShardExecutorConfig {
        ShardExecutorConfig { workers: 4, engine_workers: 1, channel_depth: 0 }
    }
}

/// One tagged unit of work against a shared frame.
struct ShardJob {
    frame_id: u64,
    spec: ShardSpec,
    image: Arc<BinnedImage>,
    out: mpsc::SyncSender<TaggedShard>,
    gauge: Arc<ResidentGauge>,
}

/// Executor observability counters.
#[derive(Debug, Clone)]
pub struct ShardExecutorStats {
    /// Shards executed since construction.
    pub jobs: usize,
    /// Shards executed per worker (pull-based balance, Fig. 18).
    pub per_worker: Vec<usize>,
    /// Engines ever created for the checkout stack (≤ workers).
    pub engines_created: usize,
    /// Frames currently in flight (submitted, ticket not finished).
    pub frames_inflight: usize,
    /// Peak concurrently in-flight frames — > 1 is the interleaving
    /// the serial `BinTaskQueue` route could never reach.
    pub frames_inflight_peak: usize,
    /// Partial-tensor arena counters.
    pub partial_pool: PoolStats,
}

struct Shared {
    engines: Mutex<Vec<ScanEngine>>,
    engines_created: AtomicUsize,
    pool: Arc<FramePool>,
    jobs: AtomicUsize,
    per_worker: Vec<AtomicUsize>,
    inflight: AtomicUsize,
    inflight_peak: AtomicUsize,
}

/// The shared shard scheduler.  All methods take `&self`; submit from
/// any number of threads.
pub struct ShardExecutor {
    config: ShardExecutorConfig,
    tx: Mutex<Option<mpsc::Sender<ShardJob>>>,
    handles: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    frame_seq: AtomicU64,
}

impl std::fmt::Debug for ShardExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardExecutor")
            .field("workers", &self.handles.len())
            .field("jobs", &self.shared.jobs.load(Ordering::Relaxed))
            .finish()
    }
}

impl ShardExecutor {
    pub fn new(config: ShardExecutorConfig) -> ShardExecutor {
        let workers = config.workers.max(1);
        let shared = Arc::new(Shared {
            engines: Mutex::new(Vec::new()),
            engines_created: AtomicUsize::new(0),
            pool: Arc::new(FramePool::new()),
            jobs: AtomicUsize::new(0),
            per_worker: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
        });
        let (tx, rx) = mpsc::channel::<ShardJob>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for worker_id in 0..workers {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let engine_workers = config.engine_workers.max(1);
            let h = std::thread::Builder::new()
                .name(format!("inthist-shard-{worker_id}"))
                .spawn(move || worker_loop(&rx, &shared, worker_id, engine_workers))
                .expect("spawn shard worker");
            handles.push(h);
        }
        ShardExecutor {
            config,
            tx: Mutex::new(Some(tx)),
            handles,
            shared,
            frame_seq: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    pub fn config(&self) -> &ShardExecutorConfig {
        &self.config
    }

    pub fn stats(&self) -> ShardExecutorStats {
        let s = &self.shared;
        ShardExecutorStats {
            jobs: s.jobs.load(Ordering::Relaxed),
            per_worker: s.per_worker.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            engines_created: s.engines_created.load(Ordering::Relaxed),
            frames_inflight: s.inflight.load(Ordering::Relaxed),
            frames_inflight_peak: s.inflight_peak.load(Ordering::Relaxed),
            partial_pool: s.pool.stats(),
        }
    }

    /// Submit every shard of `plan` against `image`, returning the
    /// frame's ticket.  Non-blocking: shards queue behind whatever
    /// other frames already have in flight.
    pub fn submit(&self, image: &Arc<BinnedImage>, plan: &ShardPlan) -> Result<FrameTicket> {
        if (image.h, image.w, image.bins) != (plan.h, plan.w, plan.bins) {
            return Err(anyhow!(
                "plan {}x{}x{} does not match image {}x{}x{}",
                plan.bins,
                plan.h,
                plan.w,
                image.bins,
                image.h,
                image.w
            ));
        }
        let tx = {
            let guard = self.tx.lock().expect("submit lock");
            guard.as_ref().expect("executor already shut down").clone()
        };
        let frame_id = self.frame_seq.fetch_add(1, Ordering::Relaxed);
        let depth = if self.config.channel_depth == 0 {
            self.handles.len()
        } else {
            self.config.channel_depth
        };
        let (out_tx, out_rx) = mpsc::sync_channel::<TaggedShard>(depth.max(1));
        let gauge = Arc::new(ResidentGauge::default());
        for spec in &plan.shards {
            tx.send(ShardJob {
                frame_id,
                spec: *spec,
                image: Arc::clone(image),
                out: out_tx.clone(),
                gauge: Arc::clone(&gauge),
            })
            .map_err(|_| anyhow!("all shard workers exited"))?;
        }
        // Count the frame only once its shards are all queued: a
        // failed submit returns without a ticket, so nothing would
        // ever settle the counter.
        let now = self.shared.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.inflight_peak.fetch_max(now, Ordering::Relaxed);
        Ok(FrameTicket {
            frame_id,
            plan: plan.clone(),
            rx: out_rx,
            gauge,
            shared: Arc::clone(&self.shared),
            settled: false,
            t_submit: Instant::now(),
        })
    }

    /// Close the queue and join the workers (also done on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx.lock().expect("submit lock").take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ShardExecutor {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    rx: &Mutex<mpsc::Receiver<ShardJob>>,
    shared: &Shared,
    worker_id: usize,
    engine_workers: usize,
) {
    // Persistent sub-image buffer: reused across jobs, reallocating
    // only when a larger strip arrives.
    let mut sub = BinnedImage { h: 0, w: 0, bins: 1, data: Vec::new() };
    loop {
        let job = match rx.lock().expect("shard queue lock").recv() {
            Ok(j) => j,
            Err(_) => break, // queue closed: drain done, exit
        };
        let spec = job.spec;
        let w = job.image.w;
        // Slice rows [row0, row0+nrows) and shift values so this
        // shard's bins land in [0, nbins) — the device pool's bin
        // grouping trick, applied per row strip.
        sub.h = spec.nrows;
        sub.w = w;
        sub.bins = spec.nbins;
        sub.data.clear();
        sub.data.reserve(spec.nrows * w);
        let lo = spec.bin0 as i32;
        let hi = (spec.bin0 + spec.nbins) as i32;
        let src = &job.image.data[spec.row0 * w..(spec.row0 + spec.nrows) * w];
        sub.data.extend(src.iter().map(|&v| if v >= lo && v < hi { v - lo } else { -1 }));

        let mut engine = match shared.engines.lock().expect("engine stack lock").pop() {
            Some(e) => e,
            None => {
                shared.engines_created.fetch_add(1, Ordering::Relaxed);
                ScanEngine::new(engine_workers)
            }
        };
        let mut partial = shared.pool.acquire(spec.nbins, spec.nrows, w);
        job.gauge.add(spec.nbins * spec.nrows * w * 4);
        let t0 = Instant::now();
        engine.compute_into(&sub, &mut partial);
        let kernel_time = t0.elapsed();
        shared.engines.lock().expect("engine stack lock").push(engine);
        shared.jobs.fetch_add(1, Ordering::Relaxed);
        shared.per_worker[worker_id].fetch_add(1, Ordering::Relaxed);

        let nbytes = partial.nbytes();
        let tagged = TaggedShard { frame_id: job.frame_id, spec, partial, worker: worker_id, kernel_time };
        if let Err(e) = job.out.send(tagged) {
            // Ticket dropped before reassembly: recycle and settle.
            shared.pool.release(e.0.partial);
            job.gauge.sub(nbytes);
        }
    }
}

/// Report of one reassembled frame (mirrors
/// [`TaskQueueReport`](crate::coordinator::task_queue::TaskQueueReport)
/// so Fig. 18 comparisons line up).
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub frame_id: u64,
    pub shards: usize,
    /// Submit → reassembly-complete wall time.
    pub wall: Duration,
    /// Per-shard kernel times indexed by `shard_id` (for the
    /// predicted-vs-measured comparison).
    pub kernel_by_shard: Vec<Duration>,
    /// Shards completed per worker.
    pub per_worker: Vec<usize>,
    /// Peak resident bytes of this frame (partials in flight + reorder
    /// buffer + carries + scratch) — the counter the memory budget is
    /// asserted against.
    pub peak_resident_bytes: usize,
}

impl ShardReport {
    pub fn fps(&self) -> f64 {
        1.0 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Sum of per-shard kernel times — the one-worker serial estimate.
    pub fn serial_kernel_time(&self) -> Duration {
        self.kernel_by_shard.iter().sum()
    }

    pub fn efficiency(&self, workers: usize) -> f64 {
        self.serial_kernel_time().as_secs_f64()
            / (workers.max(1) as f64 * self.wall.as_secs_f64().max(1e-12))
    }
}

/// Handle on one submitted frame.  Drive it with one of the
/// `reassemble_*` methods; dropping it without reassembling cancels
/// cleanly (in-flight shards are recycled as they complete).
pub struct FrameTicket {
    frame_id: u64,
    plan: ShardPlan,
    rx: mpsc::Receiver<TaggedShard>,
    gauge: Arc<ResidentGauge>,
    shared: Arc<Shared>,
    settled: bool,
    t_submit: Instant,
}

impl FrameTicket {
    pub fn frame_id(&self) -> u64 {
        self.frame_id
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// This frame's resident-bytes gauge (live view; peak is also in
    /// the final [`ShardReport`]).
    pub fn gauge(&self) -> &ResidentGauge {
        &self.gauge
    }

    /// Drain every shard into `sink`.
    pub fn reassemble(mut self, sink: &mut dyn ShardSink) -> Result<ShardReport> {
        let n = self.plan.shards.len();
        let mut kernel_by_shard = vec![Duration::ZERO; n];
        let mut per_worker = vec![0usize; self.shared.per_worker.len()];
        let mut reasm =
            Reassembler::new(&self.plan, Some(Arc::clone(&self.shared.pool)), Arc::clone(&self.gauge));
        for _ in 0..n {
            let shard = self
                .rx
                .recv()
                .context("shard workers hung up mid-frame")?;
            let id = shard.spec.shard_id;
            if id < n {
                kernel_by_shard[id] = shard.kernel_time;
            }
            if shard.worker < per_worker.len() {
                per_worker[shard.worker] += 1;
            }
            reasm.accept(shard, sink)?;
        }
        if !reasm.finished() {
            return Err(anyhow!("frame {} reassembly incomplete", self.frame_id));
        }
        drop(reasm); // settle carry/scratch charges before reading peak
        self.settle();
        Ok(ShardReport {
            frame_id: self.frame_id,
            shards: n,
            wall: self.t_submit.elapsed(),
            kernel_by_shard,
            per_worker,
            peak_resident_bytes: self.gauge.peak(),
        })
    }

    /// Drain into a caller tensor in host RAM.
    pub fn reassemble_into(self, out: &mut IntegralHistogram) -> Result<ShardReport> {
        let (bins, h, w) = (self.plan.bins, self.plan.h, self.plan.w);
        let mut sink = RamSink::new(out, bins, h, w);
        self.reassemble(&mut sink)
    }

    /// Drain into a fresh spill-backed [`TensorStore`] — the
    /// out-of-core path: peak host residency stays near the plan's
    /// per-shard budget × slack, never the full tensor.
    pub fn reassemble_spilled(self) -> Result<(TensorStore, ShardReport)> {
        let mut store = TensorStore::spill(self.plan.bins, self.plan.h, self.plan.w)?;
        let report = self.reassemble(&mut store)?;
        Ok((store, report))
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

impl Drop for FrameTicket {
    fn drop(&mut self) {
        self.settle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::shard::planner::{ShardPlanner, ShardPolicy};
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> Arc<BinnedImage> {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        Arc::new(BinnedImage::new(h, w, bins, data))
    }

    fn planner(budget: usize, workers: usize) -> ShardPlanner {
        ShardPlanner::new(ShardPolicy {
            memory_budget: budget,
            workers,
            ..ShardPolicy::default()
        })
    }

    #[test]
    fn one_frame_matches_algorithm_1() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 3, ..Default::default() });
        let img = random_image(50, 38, 9, 1);
        let plan = planner(32 << 10, 3).plan(9, 50, 38);
        assert!(plan.shards.len() > 3, "want real fan-out");
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let report = ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        assert_eq!(report.shards, plan.shards.len());
        assert_eq!(report.per_worker.iter().sum::<usize>(), plan.shards.len());
        assert!(report.serial_kernel_time() > Duration::ZERO);
        assert!(report.efficiency(3) > 0.0);
    }

    #[test]
    fn interleaved_frames_reassemble_independently() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let plan = planner(16 << 10, 2).plan(6, 40, 30);
        let imgs: Vec<_> = (0..3).map(|s| random_image(40, 30, 6, 10 + s)).collect();
        // Submit all three frames before draining any: shards of all
        // frames share the queue.
        let tickets: Vec<_> =
            imgs.iter().map(|img| exec.submit(img, &plan).expect("submit")).collect();
        assert!(exec.stats().frames_inflight_peak >= 3);
        for (img, ticket) in imgs.iter().zip(tickets) {
            let mut out = IntegralHistogram::zeros(0, 0, 0);
            ticket.reassemble_into(&mut out).expect("reassemble");
            let expected = integral_histogram_seq(img);
            assert_eq!(expected.max_abs_diff(&out), 0.0);
        }
        let stats = exec.stats();
        assert_eq!(stats.jobs, 3 * plan.shards.len());
        assert_eq!(stats.frames_inflight, 0, "tickets settle on completion");
        assert!(stats.engines_created <= 2, "engines recycle through the checkout stack");
    }

    #[test]
    fn concurrent_submitters_stay_bit_identical() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 3, ..Default::default() });
        let plan = planner(24 << 10, 3).plan(5, 36, 28);
        std::thread::scope(|scope| {
            for seed in 0..4u64 {
                let exec = &exec;
                let plan = &plan;
                scope.spawn(move || {
                    let img = random_image(36, 28, 5, 40 + seed);
                    for _ in 0..3 {
                        let ticket = exec.submit(&img, plan).expect("submit");
                        let mut out = IntegralHistogram::zeros(0, 0, 0);
                        ticket.reassemble_into(&mut out).expect("reassemble");
                        let expected = integral_histogram_seq(&img);
                        assert_eq!(expected.max_abs_diff(&out), 0.0);
                    }
                });
            }
        });
        assert_eq!(exec.stats().jobs, 4 * 3 * plan.shards.len());
    }

    #[test]
    fn dropped_ticket_cancels_cleanly() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(32, 32, 4, 5);
        let plan = planner(8 << 10, 2).plan(4, 32, 32);
        let ticket = exec.submit(&img, &plan).expect("submit");
        drop(ticket);
        // The executor must still serve later frames correctly.
        let ticket = exec.submit(&img, &plan).expect("submit again");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        assert_eq!(exec.stats().frames_inflight, 0);
    }

    #[test]
    fn mismatched_plan_is_rejected() {
        let exec = ShardExecutor::new(ShardExecutorConfig::default());
        let img = random_image(16, 16, 4, 2);
        let plan = planner(1 << 20, 2).plan(4, 32, 16);
        assert!(exec.submit(&img, &plan).is_err());
    }

    #[test]
    fn spilled_reassembly_matches_ram() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(45, 21, 7, 8);
        let plan = planner(10 << 10, 2).plan(7, 45, 21);
        let (store, report) = exec.submit(&img, &plan).expect("submit").reassemble_spilled().expect("spill");
        let expected = integral_histogram_seq(&img);
        let back = store.to_histogram().expect("materialize");
        assert_eq!(expected.max_abs_diff(&back), 0.0);
        assert!(report.peak_resident_bytes < expected.nbytes(), "never held the full tensor");
    }
}
