//! `ShardExecutor` — the shared multi-job scheduler that runs shards
//! from any number of in-flight frames interleaved over one worker
//! set.
//!
//! This retires the two limits the ROADMAP called out in the PR-2
//! serving layer: the `BinTaskQueue` ran **one job per pool** and the
//! `Server` **serialized whole frames** on it (head-of-line blocking —
//! a queued 4k frame stalled every other large request).  Here:
//!
//! * one fixed set of worker threads pulls `(frame_id, shard_id)`
//!   tagged jobs from a single FIFO — shards of different frames
//!   interleave freely, so frame N+1's shards fill the drain tail of
//!   frame N (the idle slots a lone frame leaves when its last shards
//!   occupy fewer workers than exist);
//! * each worker computes on a [`ScanEngine`] checked out of a shared
//!   LIFO stack (warm scratch and parked
//!   [`WorkerPool`](crate::histogram::engine::WorkerPool) reused
//!   across jobs and frames), with a persistent per-thread sub-image
//!   buffer — the steady state allocates no per-shard buffers beyond
//!   the pooled partial tensors;
//! * results stream back through a **bounded** per-frame channel
//!   (capacity ≈ workers), so a slow consumer exerts backpressure on
//!   the workers instead of buffering unboundedly — the discipline
//!   that keeps the out-of-core path inside its memory budget;
//! * the caller holds a [`FrameTicket`] per submitted frame and drives
//!   reassembly (into RAM or a spilled
//!   [`TensorStore`](crate::shard::TensorStore)) on its own thread,
//!   overlapping frame N's reassembly with frame N+1's compute.
//!
//! **Supervision.** Shard compute runs under `catch_unwind` with a
//! bounded retry budget ([`ShardExecutorConfig::max_attempts`]).  A
//! panicking attempt discards the involved `ScanEngine` (its internal
//! scheduler state is suspect; a fresh one is built on next checkout)
//! and recycles the partial tensor; a shard that exhausts its budget
//! delivers a typed [`ShardError`] through the frame's channel instead
//! of hanging the ticket.  Reassembly has deadline variants
//! (`reassemble_*_deadline`), so the full contract is: every submitted
//! frame either reassembles **bit-identical** to a fault-free run or
//! resolves to a typed error within its deadline.  Chaos coverage for
//! this contract lives in `tests/fault_property.rs` (build with
//! `--features fault-injection`).
//!
//! Ordering note: when one thread holds several tickets it must
//! reassemble them in submission order (jobs leave the FIFO in that
//! order, and the bounded channels are what bound memory); tickets
//! held by different threads — the server's session model — drain
//! independently in any order.

use crate::coordinator::frame_pool::{FramePool, PoolStats};
use crate::fault::{FaultAction, FaultInjector, FaultSite};
use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::shard::planner::{ShardPlan, ShardSpec};
use crate::shard::reassemble::{RamSink, Reassembler, ShardSink};
use crate::shard::store::TensorStore;
use crate::shard::{ResidentGauge, ShardError, TaggedShard};
use crate::tune::{Calibrator, TunedPlanner, TuneStats};
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Executor configuration.
#[derive(Debug, Clone, Copy)]
pub struct ShardExecutorConfig {
    /// Worker threads (the paper's device count; Fig. 18 uses 4).
    pub workers: usize,
    /// `ScanEngine` thread budget per shard.  1 by default: shard-level
    /// parallelism comes from the worker set, not from one shard
    /// grabbing every core.
    pub engine_workers: usize,
    /// Completed-shard backpressure depth per frame (0 ⇒ `workers`).
    pub channel_depth: usize,
    /// Compute attempts per shard before a typed [`ShardError`] is
    /// delivered (≥ 1; panicking attempts are caught and retried).
    pub max_attempts: usize,
}

impl Default for ShardExecutorConfig {
    fn default() -> ShardExecutorConfig {
        ShardExecutorConfig { workers: 4, engine_workers: 1, channel_depth: 0, max_attempts: 3 }
    }
}

/// What flows through a frame's result channel: a completed shard or
/// the typed failure that retired it.  `pub(crate)` so the proc-plane
/// supervisor ([`crate::proc`]) can feed the same [`FrameTicket`]
/// drain loop from child-process results.
pub(crate) type ShardMsg = std::result::Result<TaggedShard, ShardError>;

/// One tagged unit of work against a shared frame.
struct ShardJob {
    frame_id: u64,
    spec: ShardSpec,
    image: Arc<BinnedImage>,
    out: mpsc::SyncSender<ShardMsg>,
    gauge: Arc<ResidentGauge>,
    /// Deadline propagated from [`ShardExecutor::submit_with_deadline`]:
    /// a shard whose frame has already blown its deadline is dropped
    /// *before* compute (typed, counted) instead of burning a worker.
    expires: Option<Instant>,
    /// `(deadline, expected_shards)` needed to type the skip error.
    deadline: Duration,
    expected: usize,
}

/// Executor observability counters.
#[derive(Debug, Clone)]
pub struct ShardExecutorStats {
    /// Shards retired (success or typed failure) since construction.
    pub jobs: usize,
    /// Shards retired per worker (pull-based balance, Fig. 18).
    pub per_worker: Vec<usize>,
    /// Engines ever created for the checkout stack (≤ workers in a
    /// fault-free run; grows by one per discarded engine).
    pub engines_created: usize,
    /// Engines discarded after a caught compute panic.
    pub engines_discarded: usize,
    /// Frames currently in flight (submitted, ticket not finished).
    pub frames_inflight: usize,
    /// Peak concurrently in-flight frames — > 1 is the interleaving
    /// the serial `BinTaskQueue` route could never reach.
    pub frames_inflight_peak: usize,
    /// Compute attempts that failed (caught panic or spurious error).
    pub attempt_failures: usize,
    /// The subset of `attempt_failures` that were caught panics.
    pub attempt_panics: usize,
    /// Shards that succeeded after ≥ 1 failed attempt.
    pub shards_recovered: usize,
    /// Shards that exhausted their retry budget (typed error sent).
    pub shards_failed: usize,
    /// Shards dropped before compute because their frame's deadline
    /// (from [`ShardExecutor::submit_with_deadline`]) had already
    /// expired when a worker picked them up.
    pub shards_skipped_deadline: usize,
    /// Frames that resolved to a typed [`ShardError`].
    pub frames_failed: usize,
    /// Tickets dropped before completing and without a typed error.
    pub frames_abandoned: usize,
    /// Worker threads still alive (counter-asserted liveness).
    pub workers_alive: usize,
    /// Partial-tensor arena counters.
    pub partial_pool: PoolStats,
    /// Tuning-cache counters when the executor was built with a
    /// calibrator ([`ShardExecutor::with_instruments`]).
    pub tune: Option<TuneStats>,
}

pub(crate) struct Shared {
    engines: Mutex<Vec<ScanEngine>>,
    engines_created: AtomicUsize,
    engines_discarded: AtomicUsize,
    pool: Arc<FramePool>,
    jobs: AtomicUsize,
    per_worker: Vec<AtomicUsize>,
    inflight: AtomicUsize,
    inflight_peak: AtomicUsize,
    max_attempts: usize,
    faults: Option<Arc<FaultInjector>>,
    /// Shared auto-tuner: every checked-out engine plans through it and
    /// feeds its tile timings back to the calibrator, so live shard
    /// traffic keeps refining the estimates the planner costs with.
    tuner: Option<Arc<TunedPlanner>>,
    attempt_failures: AtomicUsize,
    attempt_panics: AtomicUsize,
    shards_recovered: AtomicUsize,
    shards_failed: AtomicUsize,
    shards_skipped_deadline: AtomicUsize,
    frames_failed: AtomicUsize,
    frames_abandoned: AtomicUsize,
}

impl Shared {
    /// Ticket bookkeeping state for an *external* executor — the
    /// proc-plane supervisor drives child processes instead of the
    /// in-process worker loop, but reuses [`FrameTicket`] (and so the
    /// whole reassembly/deadline/spill contract) verbatim.  `workers`
    /// sizes the per-worker tally ([`TaggedShard::worker`] indexes it).
    pub(crate) fn external(workers: usize, max_attempts: usize) -> Arc<Shared> {
        Arc::new(Shared {
            engines: Mutex::new(Vec::new()),
            engines_created: AtomicUsize::new(0),
            engines_discarded: AtomicUsize::new(0),
            pool: Arc::new(FramePool::new()),
            jobs: AtomicUsize::new(0),
            per_worker: (0..workers.max(1)).map(|_| AtomicUsize::new(0)).collect(),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
            max_attempts: max_attempts.max(1),
            faults: None,
            tuner: None,
            attempt_failures: AtomicUsize::new(0),
            attempt_panics: AtomicUsize::new(0),
            shards_recovered: AtomicUsize::new(0),
            shards_failed: AtomicUsize::new(0),
            shards_skipped_deadline: AtomicUsize::new(0),
            frames_failed: AtomicUsize::new(0),
            frames_abandoned: AtomicUsize::new(0),
        })
    }

    /// Count one submitted frame (external drivers call this once per
    /// ticket, after its shards are safely queued).
    pub(crate) fn note_submitted(&self) {
        let now = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Count one retired shard against `worker`'s tally.
    pub(crate) fn note_job(&self, worker: usize) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.per_worker.get(worker) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Check out a pooled partial tensor (external drivers materialize
    /// child results into these so reassembly recycles allocations the
    /// same way the in-process path does).
    pub(crate) fn acquire_partial(&self, bins: usize, h: usize, w: usize) -> IntegralHistogram {
        self.pool.acquire(bins, h, w)
    }

    /// Return a partial checked out with [`Self::acquire_partial`] that
    /// never reached reassembly (dropped ticket, failed frame).
    pub(crate) fn release_partial(&self, t: IntegralHistogram) {
        self.pool.release(t);
    }

    /// Count one shard dropped pre-compute on an expired deadline.
    pub(crate) fn note_skipped_deadline(&self) {
        self.shards_skipped_deadline.fetch_add(1, Ordering::Relaxed);
    }
}

/// The shared shard scheduler.  All methods take `&self`; submit from
/// any number of threads.
pub struct ShardExecutor {
    config: ShardExecutorConfig,
    tx: Mutex<Option<mpsc::Sender<ShardJob>>>,
    handles: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    frame_seq: AtomicU64,
}

impl std::fmt::Debug for ShardExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardExecutor")
            .field("workers", &self.handles.len())
            .field("jobs", &self.shared.jobs.load(Ordering::Relaxed))
            .finish()
    }
}

impl ShardExecutor {
    pub fn new(config: ShardExecutorConfig) -> ShardExecutor {
        ShardExecutor::build(config, None)
    }

    /// Build an executor whose workers consult `faults` at the
    /// `ShardCompute` site (and whose spilled reassembly consults it at
    /// the spill sites).  Inert unless the crate was compiled with
    /// `--features fault-injection`.
    pub fn with_faults(config: ShardExecutorConfig, faults: Arc<FaultInjector>) -> ShardExecutor {
        ShardExecutor::build(config, Some(faults), None)
    }

    /// Build an executor with any combination of instruments: a fault
    /// injector (chaos) and/or a calibrator (auto-tuned engines whose
    /// measured tile timings flow back into the calibration loop).
    pub fn with_instruments(
        config: ShardExecutorConfig,
        faults: Option<Arc<FaultInjector>>,
        calibrator: Option<Arc<Calibrator>>,
    ) -> ShardExecutor {
        ShardExecutor::build(config, faults, calibrator)
    }

    fn build(
        config: ShardExecutorConfig,
        faults: Option<Arc<FaultInjector>>,
        calibrator: Option<Arc<Calibrator>>,
    ) -> ShardExecutor {
        let workers = config.workers.max(1);
        let shared = Arc::new(Shared {
            engines: Mutex::new(Vec::new()),
            engines_created: AtomicUsize::new(0),
            engines_discarded: AtomicUsize::new(0),
            pool: Arc::new(FramePool::new()),
            jobs: AtomicUsize::new(0),
            per_worker: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
            max_attempts: config.max_attempts.max(1),
            faults,
            tuner: calibrator.map(|c| Arc::new(TunedPlanner::new(c))),
            attempt_failures: AtomicUsize::new(0),
            attempt_panics: AtomicUsize::new(0),
            shards_recovered: AtomicUsize::new(0),
            shards_failed: AtomicUsize::new(0),
            shards_skipped_deadline: AtomicUsize::new(0),
            frames_failed: AtomicUsize::new(0),
            frames_abandoned: AtomicUsize::new(0),
        });
        let (tx, rx) = mpsc::channel::<ShardJob>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for worker_id in 0..workers {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let engine_workers = config.engine_workers.max(1);
            let h = std::thread::Builder::new()
                .name(format!("inthist-shard-{worker_id}"))
                .spawn(move || worker_loop(&rx, &shared, worker_id, engine_workers))
                .expect("spawn shard worker");
            handles.push(h);
        }
        ShardExecutor {
            config,
            tx: Mutex::new(Some(tx)),
            handles,
            shared,
            frame_seq: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Worker threads that have not exited (each worker's loop only
    /// ends at shutdown or on a defect the supervisor cannot catch, so
    /// alive < workers is a health-check red flag).
    pub fn workers_alive(&self) -> usize {
        self.handles.iter().filter(|h| !h.is_finished()).count()
    }

    pub fn config(&self) -> &ShardExecutorConfig {
        &self.config
    }

    /// The injector wired at construction, if any.
    pub fn faults(&self) -> Option<&Arc<FaultInjector>> {
        self.shared.faults.as_ref()
    }

    /// The shared auto-tuner, when built with a calibrator.
    pub fn tuner(&self) -> Option<&Arc<TunedPlanner>> {
        self.shared.tuner.as_ref()
    }

    pub fn stats(&self) -> ShardExecutorStats {
        let s = &self.shared;
        ShardExecutorStats {
            jobs: s.jobs.load(Ordering::Relaxed),
            per_worker: s.per_worker.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            engines_created: s.engines_created.load(Ordering::Relaxed),
            engines_discarded: s.engines_discarded.load(Ordering::Relaxed),
            frames_inflight: s.inflight.load(Ordering::Relaxed),
            frames_inflight_peak: s.inflight_peak.load(Ordering::Relaxed),
            attempt_failures: s.attempt_failures.load(Ordering::Relaxed),
            attempt_panics: s.attempt_panics.load(Ordering::Relaxed),
            shards_recovered: s.shards_recovered.load(Ordering::Relaxed),
            shards_failed: s.shards_failed.load(Ordering::Relaxed),
            shards_skipped_deadline: s.shards_skipped_deadline.load(Ordering::Relaxed),
            frames_failed: s.frames_failed.load(Ordering::Relaxed),
            frames_abandoned: s.frames_abandoned.load(Ordering::Relaxed),
            workers_alive: self.workers_alive(),
            partial_pool: s.pool.stats(),
            tune: s.tuner.as_ref().map(|t| t.stats()),
        }
    }

    /// Submit every shard of `plan` against `image`, returning the
    /// frame's ticket.  Non-blocking: shards queue behind whatever
    /// other frames already have in flight.
    pub fn submit(&self, image: &Arc<BinnedImage>, plan: &ShardPlan) -> Result<FrameTicket> {
        self.submit_inner(image, plan, None)
    }

    /// [`Self::submit`] with a frame deadline pushed into the *queue*:
    /// workers drop this frame's shards before compute once `deadline`
    /// (measured from this call) has elapsed, so a frame that already
    /// blew its budget stops consuming worker time instead of being
    /// rejected only at reassembly.  Skips are typed
    /// ([`ShardError::DeadlineExceeded`]) and counted
    /// ([`ShardExecutorStats::shards_skipped_deadline`]).  Pair with
    /// `reassemble_*_deadline` for the drain-side bound.
    pub fn submit_with_deadline(
        &self,
        image: &Arc<BinnedImage>,
        plan: &ShardPlan,
        deadline: Duration,
    ) -> Result<FrameTicket> {
        self.submit_inner(image, plan, Some(deadline))
    }

    fn submit_inner(
        &self,
        image: &Arc<BinnedImage>,
        plan: &ShardPlan,
        deadline: Option<Duration>,
    ) -> Result<FrameTicket> {
        if (image.h, image.w, image.bins) != (plan.h, plan.w, plan.bins) {
            return Err(anyhow!(
                "plan {}x{}x{} does not match image {}x{}x{}",
                plan.bins,
                plan.h,
                plan.w,
                image.bins,
                image.h,
                image.w
            ));
        }
        let tx = {
            let guard = lock_recover(&self.tx);
            guard.as_ref().expect("executor already shut down").clone()
        };
        let frame_id = self.frame_seq.fetch_add(1, Ordering::Relaxed);
        let depth = if self.config.channel_depth == 0 {
            self.handles.len()
        } else {
            self.config.channel_depth
        };
        let (out_tx, out_rx) = mpsc::sync_channel::<ShardMsg>(depth.max(1));
        let gauge = Arc::new(ResidentGauge::default());
        let expires = deadline.map(|d| Instant::now() + d);
        for spec in &plan.shards {
            tx.send(ShardJob {
                frame_id,
                spec: *spec,
                image: Arc::clone(image),
                out: out_tx.clone(),
                gauge: Arc::clone(&gauge),
                expires,
                deadline: deadline.unwrap_or(Duration::ZERO),
                expected: plan.shards.len(),
            })
            .map_err(|_| anyhow!("all shard workers exited"))?;
        }
        // Count the frame only once its shards are all queued: a
        // failed submit returns without a ticket, so nothing would
        // ever settle the counter.
        let now = self.shared.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.inflight_peak.fetch_max(now, Ordering::Relaxed);
        Ok(FrameTicket {
            frame_id,
            plan: plan.clone(),
            rx: out_rx,
            gauge,
            shared: Arc::clone(&self.shared),
            settled: false,
            finished: false,
            failed: false,
            t_submit: Instant::now(),
        })
    }

    /// Close the queue and join the workers (also done on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        lock_recover(&self.tx).take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ShardExecutor {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    rx: &Mutex<mpsc::Receiver<ShardJob>>,
    shared: &Shared,
    worker_id: usize,
    engine_workers: usize,
) {
    // Persistent sub-image buffer: reused across jobs, reallocating
    // only when a larger strip arrives.
    let mut sub = BinnedImage { h: 0, w: 0, bins: 1, data: Vec::new() };
    loop {
        let job = match lock_recover(rx).recv() {
            Ok(j) => j,
            Err(_) => break, // queue closed: drain done, exit
        };
        let spec = job.spec;
        // Deadline-aware scheduling: a shard whose frame already blew
        // its deadline is dropped here, before any slicing or compute —
        // the queue time was the budget, the worker slot goes to a
        // frame that can still make it.  Typed + counted; the ticket's
        // drain loop surfaces the first such error.
        if let Some(exp) = job.expires {
            if Instant::now() >= exp {
                shared.shards_skipped_deadline.fetch_add(1, Ordering::Relaxed);
                shared.jobs.fetch_add(1, Ordering::Relaxed);
                shared.per_worker[worker_id].fetch_add(1, Ordering::Relaxed);
                let _ = job.out.send(Err(ShardError::DeadlineExceeded {
                    frame_id: job.frame_id,
                    deadline: job.deadline,
                    completed: 0,
                    expected: job.expected,
                }));
                continue;
            }
        }
        let w = job.image.w;
        // Slice rows [row0, row0+nrows) and shift values so this
        // shard's bins land in [0, nbins) — the device pool's bin
        // grouping trick, applied per row strip.
        sub.h = spec.nrows;
        sub.w = w;
        sub.bins = spec.nbins;
        sub.data.clear();
        sub.data.reserve(spec.nrows * w);
        let lo = spec.bin0 as i32;
        let hi = (spec.bin0 + spec.nbins) as i32;
        let src = &job.image.data[spec.row0 * w..(spec.row0 + spec.nrows) * w];
        sub.data.extend(src.iter().map(|&v| if v >= lo && v < hi { v - lo } else { -1 }));

        // Supervised compute: up to max_attempts tries; each attempt
        // consults the fault schedule, catches panics, and leaves the
        // shared state (engine stack, pool, gauge) settled either way.
        let charged = spec.nbins * spec.nrows * w * 4;
        let mut outcome: Option<(IntegralHistogram, Duration)> = None;
        let mut failures = 0usize;
        let mut panicked_last = false;
        while outcome.is_none() && failures < shared.max_attempts {
            let mut injected = shared.faults.as_ref().and_then(|f| f.decide(FaultSite::ShardCompute));
            if let Some(FaultAction::Delay(d)) = injected {
                std::thread::sleep(d); // slow worker: stall, then proceed
                injected = None;
            }
            if matches!(injected, Some(FaultAction::Error)) {
                failures += 1;
                panicked_last = false;
                shared.attempt_failures.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let mut engine = match lock_recover(&shared.engines).pop() {
                Some(e) => e,
                None => {
                    shared.engines_created.fetch_add(1, Ordering::Relaxed);
                    match &shared.tuner {
                        Some(t) => ScanEngine::with_tuner(engine_workers, Arc::clone(t)),
                        None => ScanEngine::new(engine_workers),
                    }
                }
            };
            let mut partial = shared.pool.acquire(spec.nbins, spec.nrows, w);
            job.gauge.add(charged);
            let t0 = Instant::now();
            let inject_panic = matches!(injected, Some(FaultAction::Panic));
            let run = catch_unwind(AssertUnwindSafe(|| {
                if inject_panic {
                    panic!("injected shard compute panic (worker {worker_id})");
                }
                engine.compute_into(&sub, &mut partial);
            }));
            match run {
                Ok(()) => {
                    lock_recover(&shared.engines).push(engine);
                    outcome = Some((partial, t0.elapsed()));
                }
                Err(_) => {
                    // The engine's internal scheduler may be mid-job:
                    // discard it rather than return it to the stack (a
                    // fresh engine is built on the next checkout).
                    shared.engines_discarded.fetch_add(1, Ordering::Relaxed);
                    drop(engine);
                    shared.pool.release(partial);
                    job.gauge.sub(charged);
                    failures += 1;
                    panicked_last = true;
                    shared.attempt_failures.fetch_add(1, Ordering::Relaxed);
                    shared.attempt_panics.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        shared.jobs.fetch_add(1, Ordering::Relaxed);
        shared.per_worker[worker_id].fetch_add(1, Ordering::Relaxed);
        match outcome {
            Some((partial, kernel_time)) => {
                if failures > 0 {
                    shared.shards_recovered.fetch_add(1, Ordering::Relaxed);
                }
                let tagged =
                    TaggedShard { frame_id: job.frame_id, spec, partial, worker: worker_id, kernel_time };
                if let Err(e) = job.out.send(Ok(tagged)) {
                    // Ticket dropped before reassembly: recycle and settle.
                    if let Ok(t) = e.0 {
                        shared.pool.release(t.partial);
                        job.gauge.sub(charged);
                    }
                }
            }
            None => {
                shared.shards_failed.fetch_add(1, Ordering::Relaxed);
                let err = if panicked_last {
                    ShardError::ComputePanicked {
                        frame_id: job.frame_id,
                        shard_id: spec.shard_id,
                        attempts: failures,
                    }
                } else {
                    ShardError::ComputeFailed {
                        frame_id: job.frame_id,
                        shard_id: spec.shard_id,
                        attempts: failures,
                        reason: "spurious compute error".into(),
                    }
                };
                // Ticket may already be gone; nothing else to settle.
                let _ = job.out.send(Err(err));
            }
        }
    }
}

/// Report of one reassembled frame (mirrors
/// [`TaskQueueReport`](crate::coordinator::task_queue::TaskQueueReport)
/// so Fig. 18 comparisons line up).
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub frame_id: u64,
    pub shards: usize,
    /// Submit → reassembly-complete wall time.
    pub wall: Duration,
    /// Per-shard kernel times indexed by `shard_id` (for the
    /// predicted-vs-measured comparison).
    pub kernel_by_shard: Vec<Duration>,
    /// Shards completed per worker.
    pub per_worker: Vec<usize>,
    /// Peak resident bytes of this frame (partials in flight + reorder
    /// buffer + carries + scratch) — the counter the memory budget is
    /// asserted against.
    pub peak_resident_bytes: usize,
}

impl ShardReport {
    pub fn fps(&self) -> f64 {
        1.0 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Sum of per-shard kernel times — the one-worker serial estimate.
    pub fn serial_kernel_time(&self) -> Duration {
        self.kernel_by_shard.iter().sum()
    }

    pub fn efficiency(&self, workers: usize) -> f64 {
        self.serial_kernel_time().as_secs_f64()
            / (workers.max(1) as f64 * self.wall.as_secs_f64().max(1e-12))
    }
}

/// Handle on one submitted frame.  Drive it with one of the
/// `reassemble_*` methods — the "wait" of this subsystem; each has a
/// `_deadline` variant that bounds the wait and resolves to
/// [`ShardError::DeadlineExceeded`] instead of blocking.  Dropping the
/// ticket without reassembling cancels cleanly (in-flight shards are
/// recycled as they complete, and the frame is counted abandoned).
pub struct FrameTicket {
    frame_id: u64,
    plan: ShardPlan,
    rx: mpsc::Receiver<ShardMsg>,
    gauge: Arc<ResidentGauge>,
    shared: Arc<Shared>,
    settled: bool,
    finished: bool,
    failed: bool,
    t_submit: Instant,
}

impl FrameTicket {
    /// Build a ticket for an *externally* driven frame (the proc-plane
    /// supervisor): the caller owns job dispatch and pushes
    /// [`ShardMsg`]s into the paired sender; reassembly, deadlines,
    /// spill, carry composition and settle accounting are all reused
    /// from here unchanged.  Call [`Shared::note_submitted`] once the
    /// frame's shards are queued.
    pub(crate) fn external(
        frame_id: u64,
        plan: ShardPlan,
        rx: mpsc::Receiver<ShardMsg>,
        gauge: Arc<ResidentGauge>,
        shared: Arc<Shared>,
    ) -> FrameTicket {
        FrameTicket {
            frame_id,
            plan,
            rx,
            gauge,
            shared,
            settled: false,
            finished: false,
            failed: false,
            t_submit: Instant::now(),
        }
    }

    pub fn frame_id(&self) -> u64 {
        self.frame_id
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// This frame's resident-bytes gauge (live view; peak is also in
    /// the final [`ShardReport`]).
    pub fn gauge(&self) -> &ResidentGauge {
        &self.gauge
    }

    /// Drain every shard into `sink` (unbounded wait).
    pub fn reassemble(self, sink: &mut dyn ShardSink) -> std::result::Result<ShardReport, ShardError> {
        self.reassemble_with(sink, None)
    }

    /// Drain every shard into `sink`, or fail typed once `deadline`
    /// (measured from this call) elapses.
    pub fn reassemble_deadline(
        self,
        sink: &mut dyn ShardSink,
        deadline: Duration,
    ) -> std::result::Result<ShardReport, ShardError> {
        self.reassemble_with(sink, Some(deadline))
    }

    /// Drain into a caller tensor in host RAM.
    pub fn reassemble_into(
        self,
        out: &mut IntegralHistogram,
    ) -> std::result::Result<ShardReport, ShardError> {
        let (bins, h, w) = (self.plan.bins, self.plan.h, self.plan.w);
        let mut sink = RamSink::new(out, bins, h, w);
        self.reassemble_with(&mut sink, None)
    }

    /// [`Self::reassemble_into`] with a deadline.
    pub fn reassemble_into_deadline(
        self,
        out: &mut IntegralHistogram,
        deadline: Duration,
    ) -> std::result::Result<ShardReport, ShardError> {
        let (bins, h, w) = (self.plan.bins, self.plan.h, self.plan.w);
        let mut sink = RamSink::new(out, bins, h, w);
        self.reassemble_with(&mut sink, Some(deadline))
    }

    /// Drain into a fresh spill-backed [`TensorStore`] — the
    /// out-of-core path: peak host residency stays near the plan's
    /// per-shard budget × slack, never the full tensor.
    pub fn reassemble_spilled(self) -> std::result::Result<(TensorStore, ShardReport), ShardError> {
        self.reassemble_spilled_with(None)
    }

    /// [`Self::reassemble_spilled`] with a deadline.
    pub fn reassemble_spilled_deadline(
        self,
        deadline: Duration,
    ) -> std::result::Result<(TensorStore, ShardReport), ShardError> {
        self.reassemble_spilled_with(Some(deadline))
    }

    fn reassemble_spilled_with(
        mut self,
        deadline: Option<Duration>,
    ) -> std::result::Result<(TensorStore, ShardReport), ShardError> {
        let mut store = match TensorStore::spill(self.plan.bins, self.plan.h, self.plan.w) {
            Ok(s) => s,
            Err(e) => {
                let frame_id = self.frame_id;
                self.fail();
                return Err(ShardError::Reassembly {
                    frame_id,
                    reason: format!("spill store: {e:#}"),
                });
            }
        };
        if let Some(f) = &self.shared.faults {
            store.set_faults(Arc::clone(f));
        }
        let report = self.reassemble_with(&mut store, deadline)?;
        Ok((store, report))
    }

    /// Core drain loop.  `deadline`, when given, is measured from this
    /// call; on expiry the frame resolves to
    /// [`ShardError::DeadlineExceeded`] carrying its progress.  The
    /// ticket is consumed either way — workers recycle any shards that
    /// land after the ticket is gone.
    fn reassemble_with(
        mut self,
        sink: &mut dyn ShardSink,
        deadline: Option<Duration>,
    ) -> std::result::Result<ShardReport, ShardError> {
        let frame_id = self.frame_id;
        let n = self.plan.shards.len();
        let t_start = Instant::now();
        let mut kernel_by_shard = vec![Duration::ZERO; n];
        let mut per_worker = vec![0usize; self.shared.per_worker.len()];
        let mut reasm =
            Reassembler::new(&self.plan, Some(Arc::clone(&self.shared.pool)), Arc::clone(&self.gauge));
        for done in 0..n {
            let msg = match deadline {
                None => match self.rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        self.fail();
                        return Err(ShardError::WorkersGone { frame_id });
                    }
                },
                Some(d) => {
                    let remaining = d.saturating_sub(t_start.elapsed());
                    let timed_out = if remaining.is_zero() {
                        true
                    } else {
                        match self.rx.recv_timeout(remaining) {
                            Ok(m) => {
                                match self.consume(m, &mut reasm, sink, &mut kernel_by_shard, &mut per_worker, n)
                                {
                                    Ok(()) => continue,
                                    Err(e) => return Err(e),
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => true,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                self.fail();
                                return Err(ShardError::WorkersGone { frame_id });
                            }
                        }
                    };
                    debug_assert!(timed_out);
                    self.fail();
                    return Err(ShardError::DeadlineExceeded {
                        frame_id,
                        deadline: d,
                        completed: done,
                        expected: n,
                    });
                }
            };
            if let Err(e) = self.consume(msg, &mut reasm, sink, &mut kernel_by_shard, &mut per_worker, n) {
                return Err(e);
            }
        }
        if !reasm.finished() {
            self.fail();
            return Err(ShardError::Reassembly {
                frame_id,
                reason: format!("incomplete: {}/{} shards committed", reasm.accepted(), n),
            });
        }
        drop(reasm); // settle carry/scratch charges before reading peak
        self.finished = true;
        self.settle();
        Ok(ShardReport {
            frame_id,
            shards: n,
            wall: self.t_submit.elapsed(),
            kernel_by_shard,
            per_worker,
            peak_resident_bytes: self.gauge.peak(),
        })
    }

    /// Fold one channel message into the reassembly state; `self.fail()`
    /// has already been applied when this returns `Err`.
    fn consume(
        &mut self,
        msg: ShardMsg,
        reasm: &mut Reassembler,
        sink: &mut dyn ShardSink,
        kernel_by_shard: &mut [Duration],
        per_worker: &mut [usize],
        n: usize,
    ) -> std::result::Result<(), ShardError> {
        let frame_id = self.frame_id;
        let shard = match msg {
            Ok(s) => s,
            Err(e) => {
                self.fail();
                return Err(e);
            }
        };
        let id = shard.spec.shard_id;
        if id < n {
            kernel_by_shard[id] = shard.kernel_time;
        }
        if shard.worker < per_worker.len() {
            per_worker[shard.worker] += 1;
        }
        if let Err(e) = reasm.accept(shard, sink) {
            self.fail();
            return Err(ShardError::Reassembly { frame_id, reason: format!("{e:#}") });
        }
        Ok(())
    }

    fn fail(&mut self) {
        if !self.failed && !self.finished {
            self.failed = true;
            self.shared.frames_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.settle();
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

impl Drop for FrameTicket {
    fn drop(&mut self) {
        if !self.finished && !self.failed {
            self.shared.frames_abandoned.fetch_add(1, Ordering::Relaxed);
        }
        self.settle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::shard::planner::{ShardPlanner, ShardPolicy};
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> Arc<BinnedImage> {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        Arc::new(BinnedImage::new(h, w, bins, data))
    }

    fn planner(budget: usize, workers: usize) -> ShardPlanner {
        ShardPlanner::new(ShardPolicy {
            memory_budget: budget,
            workers,
            ..ShardPolicy::default()
        })
    }

    #[test]
    fn one_frame_matches_algorithm_1() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 3, ..Default::default() });
        let img = random_image(50, 38, 9, 1);
        let plan = planner(32 << 10, 3).plan(9, 50, 38);
        assert!(plan.shards.len() > 3, "want real fan-out");
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let report = ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        assert_eq!(report.shards, plan.shards.len());
        assert_eq!(report.per_worker.iter().sum::<usize>(), plan.shards.len());
        assert!(report.serial_kernel_time() > Duration::ZERO);
        assert!(report.efficiency(3) > 0.0);
    }

    #[test]
    fn interleaved_frames_reassemble_independently() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let plan = planner(16 << 10, 2).plan(6, 40, 30);
        let imgs: Vec<_> = (0..3).map(|s| random_image(40, 30, 6, 10 + s)).collect();
        // Submit all three frames before draining any: shards of all
        // frames share the queue.
        let tickets: Vec<_> =
            imgs.iter().map(|img| exec.submit(img, &plan).expect("submit")).collect();
        assert!(exec.stats().frames_inflight_peak >= 3);
        for (img, ticket) in imgs.iter().zip(tickets) {
            let mut out = IntegralHistogram::zeros(0, 0, 0);
            ticket.reassemble_into(&mut out).expect("reassemble");
            let expected = integral_histogram_seq(img);
            assert_eq!(expected.max_abs_diff(&out), 0.0);
        }
        let stats = exec.stats();
        assert_eq!(stats.jobs, 3 * plan.shards.len());
        assert_eq!(stats.frames_inflight, 0, "tickets settle on completion");
        assert!(stats.engines_created <= 2, "engines recycle through the checkout stack");
        assert_eq!(stats.attempt_failures, 0, "fault-free run has no failed attempts");
        assert_eq!(stats.workers_alive, 2);
    }

    #[test]
    fn concurrent_submitters_stay_bit_identical() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 3, ..Default::default() });
        let plan = planner(24 << 10, 3).plan(5, 36, 28);
        std::thread::scope(|scope| {
            for seed in 0..4u64 {
                let exec = &exec;
                let plan = &plan;
                scope.spawn(move || {
                    let img = random_image(36, 28, 5, 40 + seed);
                    for _ in 0..3 {
                        let ticket = exec.submit(&img, plan).expect("submit");
                        let mut out = IntegralHistogram::zeros(0, 0, 0);
                        ticket.reassemble_into(&mut out).expect("reassemble");
                        let expected = integral_histogram_seq(&img);
                        assert_eq!(expected.max_abs_diff(&out), 0.0);
                    }
                });
            }
        });
        assert_eq!(exec.stats().jobs, 4 * 3 * plan.shards.len());
    }

    #[test]
    fn dropped_ticket_cancels_cleanly_and_counts_abandoned() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(32, 32, 4, 5);
        let plan = planner(8 << 10, 2).plan(4, 32, 32);
        let ticket = exec.submit(&img, &plan).expect("submit");
        drop(ticket);
        // The executor must still serve later frames correctly.
        let ticket = exec.submit(&img, &plan).expect("submit again");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        let stats = exec.stats();
        assert_eq!(stats.frames_inflight, 0);
        assert_eq!(stats.frames_abandoned, 1, "the dropped ticket is reported");
        assert_eq!(stats.frames_failed, 0);
    }

    #[test]
    fn mismatched_plan_is_rejected() {
        let exec = ShardExecutor::new(ShardExecutorConfig::default());
        let img = random_image(16, 16, 4, 2);
        let plan = planner(1 << 20, 2).plan(4, 32, 16);
        assert!(exec.submit(&img, &plan).is_err());
    }

    #[test]
    fn spilled_reassembly_matches_ram() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(45, 21, 7, 8);
        let plan = planner(10 << 10, 2).plan(7, 45, 21);
        let (store, report) = exec.submit(&img, &plan).expect("submit").reassemble_spilled().expect("spill");
        let expected = integral_histogram_seq(&img);
        let back = store.to_histogram().expect("materialize");
        assert_eq!(expected.max_abs_diff(&back), 0.0);
        assert!(report.peak_resident_bytes < expected.nbytes(), "never held the full tensor");
    }

    #[test]
    fn generous_deadline_completes_bit_identical() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(40, 24, 5, 13);
        let plan = planner(12 << 10, 2).plan(5, 40, 24);
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket
            .reassemble_into_deadline(&mut out, Duration::from_secs(60))
            .expect("well within deadline");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        assert_eq!(exec.stats().frames_failed, 0);
    }

    #[test]
    fn zero_deadline_fails_typed_and_executor_survives() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(40, 24, 5, 14);
        let plan = planner(12 << 10, 2).plan(5, 40, 24);
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let err = ticket
            .reassemble_into_deadline(&mut out, Duration::ZERO)
            .expect_err("zero deadline cannot be met");
        match err {
            ShardError::DeadlineExceeded { deadline, expected, .. } => {
                assert_eq!(deadline, Duration::ZERO);
                assert_eq!(expected, plan.shards.len());
            }
            other => panic!("wrong error variant: {other}"),
        }
        // A deadline miss is a frame failure, not an abandonment, and
        // must not wedge the executor.
        let stats = exec.stats();
        assert_eq!(stats.frames_failed, 1);
        assert_eq!(stats.frames_abandoned, 0);
        let ticket = exec.submit(&img, &plan).expect("submit after miss");
        let report = ticket.reassemble_into(&mut out).expect("reassemble");
        let expected_ih = integral_histogram_seq(&img);
        assert_eq!(expected_ih.max_abs_diff(&out), 0.0);
        assert_eq!(report.shards, plan.shards.len());
    }

    #[test]
    fn expired_deadline_skips_shards_before_compute() {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
        let img = random_image(40, 24, 5, 15);
        let plan = planner(12 << 10, 2).plan(5, 40, 24);
        // A zero deadline has expired by the time any worker dequeues,
        // so every shard is dropped at the queue, not at reassembly.
        let ticket = exec.submit_with_deadline(&img, &plan, Duration::ZERO).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let err = ticket.reassemble_into(&mut out).expect_err("deadline already blown");
        match err {
            ShardError::DeadlineExceeded { completed, expected, .. } => {
                assert_eq!(completed, 0, "skipped shards never computed");
                assert_eq!(expected, plan.shards.len());
            }
            other => panic!("wrong error variant: {other}"),
        }
        let stats = exec.stats();
        assert!(stats.shards_skipped_deadline >= 1, "skips are counted");
        assert_eq!(stats.attempt_failures, 0, "no compute was attempted for skips");
        // A generous queue deadline completes bit-identical, skipping
        // nothing new.
        let skipped_before = stats.shards_skipped_deadline;
        let ticket = exec
            .submit_with_deadline(&img, &plan, Duration::from_secs(60))
            .expect("submit");
        ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        assert_eq!(exec.stats().shards_skipped_deadline, skipped_before);
    }

    #[test]
    fn calibrated_executor_stays_bit_identical_and_feeds_the_loop() {
        let cal = Arc::new(Calibrator::default());
        let exec = ShardExecutor::with_instruments(
            ShardExecutorConfig { workers: 3, ..Default::default() },
            None,
            Some(Arc::clone(&cal)),
        );
        let img = random_image(50, 38, 9, 31);
        let plan = planner(32 << 10, 3).plan(9, 50, 38);
        for _ in 0..3 {
            let ticket = exec.submit(&img, &plan).expect("submit");
            let mut out = IntegralHistogram::zeros(0, 0, 0);
            ticket.reassemble_into(&mut out).expect("reassemble");
            let expected = integral_histogram_seq(&img);
            assert_eq!(expected.max_abs_diff(&out), 0.0);
        }
        let tune = exec.stats().tune.expect("tuner stats present");
        assert!(tune.misses >= 1, "shard geometry searched");
        assert!(tune.hits > 0, "repeat shards hit the shared cache");
        assert!(cal.snapshot().samples > 0, "shard timings fed the calibrator");
    }

    #[test]
    fn shard_error_converts_to_anyhow() {
        fn f() -> Result<()> {
            Err(ShardError::WorkersGone { frame_id: 7 })?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("frame 7"), "{e}");
    }
}
