//! `TensorStore` — a spill-backed integral-histogram tensor.
//!
//! The §4.6 configuration exists precisely because the output tensor —
//! not the kernel — is the scaling bottleneck (the memory-footprint
//! argument of "Memory-Efficient Design Strategy for a Parallel
//! Embedded Integral Image Computation Engine", PAPERS.md): a 64 MB
//! image at 128 bins is a 32 GB tensor no single device *or host* is
//! guaranteed to hold.  The store keeps that tensor on disk in the
//! exact Fig. 2 layout (`b×h×w` bin-major, row-major planes, one flat
//! f32 buffer) and answers the two access patterns the serving layer
//! needs without ever materializing it in RAM:
//!
//! * **streaming writes** — the [`crate::shard::Reassembler`] commits
//!   carry-corrected row strips; rows of one bin plane are contiguous
//!   in the Fig. 2 layout, so each commit is a single sequential write;
//! * **O(1) box-histogram reads** — [`TensorStore::query`] runs Eq. 2
//!   over the four corners per bin, fetched as one sorted pass over the
//!   corner offsets with one positioned read per contiguous run (the
//!   batched path; [`TensorStore::query_reference`] keeps the
//!   read-per-corner oracle), byte-for-byte the same values and the
//!   same arithmetic order as
//!   [`crate::histogram::region::region_histogram`], so results are
//!   bit-identical to the in-RAM path (property-tested in
//!   `tests/temporal_property.rs`).
//!
//! Resident cost is a file handle plus transient per-call scratch; the
//! `bytes_written` / `corner_reads` counters make the out-of-core
//! claim observable.  Stores created with [`TensorStore::spill`] are
//! temp files deleted on drop; [`TensorStore::keep`] detaches them.
//!
//! **Integrity.** Spill I/O is the one layer where silent corruption
//! (short write, bad sector, torn page) survives until a query returns
//! a wrong histogram.  Every committed row therefore carries an FNV-1a
//! checksum (4 bytes of RAM per row — `bins×h×4` total, negligible
//! against the tensor it guards), verified on [`TensorStore::read_rows`]
//! with **one reread** before a typed error: transient corruption (a
//! flipped bit on the way in) heals on the reread, persistent
//! corruption (bad bytes on disk) is reported instead of served.
//! Corner reads stay unverified — verification there would turn the
//! O(bins) Eq. 2 query into O(bins·w) row reads; `to_histogram` and
//! strip reads, the paths that feed downstream computation, are the
//! verified ones.

use crate::fault::{corrupt_bytes, FaultAction, FaultInjector, FaultSite};
use crate::histogram::region::Rect;
use crate::histogram::types::IntegralHistogram;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Context, Result};
use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a over a byte slice — cheap, endian-stable, and sensitive to
/// single-bit flips (all this layer needs to detect).
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Per-row integrity state: checksum + written flag (unwritten rows
/// are the file's zero fill and are served unverified).
struct RowCheck {
    sums: Vec<u32>,
    written: Vec<bool>,
}

/// Two sorted corner offsets whose gap is at most this many bytes are
/// fetched in one positioned read (one page of over-read is cheaper
/// than a second syscall + seek).  Large tensors keep their planes
/// megabytes apart, so coalescing never crosses planes there.
const COALESCE_GAP: u64 = 4096;

/// Monotonic suffix so concurrent spills in one process never collide.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// A `bins×h×w` f32 tensor stored in a file in Fig. 2 layout.
pub struct TensorStore {
    bins: usize,
    h: usize,
    w: usize,
    file: File,
    /// Serializes seek-based I/O on platforms without positioned
    /// reads/writes; on unix every access is a `pread`/`pwrite`, so
    /// readers never contend.
    #[cfg(not(unix))]
    io_lock: Mutex<()>,
    /// Reusable f32→LE byte scratch for commits: persistent, at most
    /// one strip large, so commits allocate nothing in steady state
    /// (it is the one store-side resident buffer; the planner's slack
    /// envelope covers it).
    write_scratch: Mutex<Vec<u8>>,
    /// Per-row checksums, indexed `bin*h + row`.
    check: Mutex<RowCheck>,
    path: PathBuf,
    delete_on_drop: bool,
    bytes_written: AtomicUsize,
    corner_reads: AtomicUsize,
    read_calls: AtomicUsize,
    verify_rereads: AtomicUsize,
    verify_failures: AtomicUsize,
    faults: Option<Arc<FaultInjector>>,
}

impl std::fmt::Debug for TensorStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TensorStore")
            .field("bins", &self.bins)
            .field("h", &self.h)
            .field("w", &self.w)
            .field("path", &self.path)
            .finish()
    }
}

impl TensorStore {
    /// Create (truncating) a store at `path` sized for `bins×h×w`.
    pub fn create(path: impl AsRef<Path>, bins: usize, h: usize, w: usize) -> Result<TensorStore> {
        assert!(bins >= 1 && h >= 1 && w >= 1, "degenerate tensor");
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create tensor store {}", path.display()))?;
        file.set_len((bins * h * w * 4) as u64).context("size tensor store")?;
        Ok(TensorStore {
            bins,
            h,
            w,
            file,
            #[cfg(not(unix))]
            io_lock: Mutex::new(()),
            write_scratch: Mutex::new(Vec::new()),
            check: Mutex::new(RowCheck {
                sums: vec![0u32; bins * h],
                written: vec![false; bins * h],
            }),
            path,
            delete_on_drop: false,
            bytes_written: AtomicUsize::new(0),
            corner_reads: AtomicUsize::new(0),
            read_calls: AtomicUsize::new(0),
            verify_rereads: AtomicUsize::new(0),
            verify_failures: AtomicUsize::new(0),
            faults: None,
        })
    }

    /// Open an *existing* store at `path` without truncating it — the
    /// cross-process read side of the proc-plane data plane (the
    /// writer `flush()`es, hands the path over the control protocol,
    /// and the reader opens it here).  The file length must match the
    /// declared geometry exactly; a mismatch is a typed error, not a
    /// silent short read.  Per-row checksums live in the *writer's*
    /// RAM only, so rows read through a reopened store are served
    /// unverified — integrity across the process boundary rides the
    /// control protocol (`ShardDone` carries a payload checksum).
    pub fn open(path: impl AsRef<Path>, bins: usize, h: usize, w: usize) -> Result<TensorStore> {
        assert!(bins >= 1 && h >= 1 && w >= 1, "degenerate tensor");
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("open tensor store {}", path.display()))?;
        let want = (bins * h * w * 4) as u64;
        let got = file.metadata().context("stat tensor store")?.len();
        if got != want {
            return Err(anyhow!(
                "tensor store {} is {got} bytes, expected {want} for {bins}x{h}x{w}",
                path.display()
            ));
        }
        Ok(TensorStore {
            bins,
            h,
            w,
            file,
            #[cfg(not(unix))]
            io_lock: Mutex::new(()),
            write_scratch: Mutex::new(Vec::new()),
            check: Mutex::new(RowCheck {
                sums: vec![0u32; bins * h],
                written: vec![false; bins * h],
            }),
            path,
            delete_on_drop: false,
            bytes_written: AtomicUsize::new(0),
            corner_reads: AtomicUsize::new(0),
            read_calls: AtomicUsize::new(0),
            verify_rereads: AtomicUsize::new(0),
            verify_failures: AtomicUsize::new(0),
            faults: None,
        })
    }

    /// Create a store on a fresh temp file, deleted when the store
    /// drops (the out-of-core serving default).
    pub fn spill(bins: usize, h: usize, w: usize) -> Result<TensorStore> {
        let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("inthist-spill-{}-{seq}.bin", std::process::id()));
        let mut store = TensorStore::create(path, bins, h, w)?;
        store.delete_on_drop = true;
        Ok(store)
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    pub fn h(&self) -> usize {
        self.h
    }

    pub fn w(&self) -> usize {
        self.w
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// On-disk size of the tensor (what RAM is *not* holding).
    pub fn nbytes(&self) -> usize {
        self.bins * self.h * self.w * 4
    }

    /// Total bytes committed through [`Self::write_rows`].
    pub fn bytes_written(&self) -> usize {
        self.bytes_written.load(Ordering::Relaxed)
    }

    /// Corner values fetched by queries (4 per bin per rect).
    pub fn corner_reads(&self) -> usize {
        self.corner_reads.load(Ordering::Relaxed)
    }

    /// Positioned reads issued against the spill file — the syscall
    /// count the batched [`Self::query`] minimizes (one per contiguous
    /// run of corner offsets, versus one per corner on the reference
    /// path).
    pub fn read_calls(&self) -> usize {
        self.read_calls.load(Ordering::Relaxed)
    }

    /// Rows reread after a checksum mismatch (transient corruption
    /// healed, or the first half of a persistent failure).
    pub fn verify_rereads(&self) -> usize {
        self.verify_rereads.load(Ordering::Relaxed)
    }

    /// Rows whose checksum still mismatched after the reread — each
    /// one surfaced as a typed error instead of wrong data.
    pub fn verify_failures(&self) -> usize {
        self.verify_failures.load(Ordering::Relaxed)
    }

    /// Wire a fault injector into the spill I/O sites (`SpillWrite`,
    /// `SpillRead`).  Inert unless built with `--features
    /// fault-injection`.
    pub fn set_faults(&mut self, faults: Arc<FaultInjector>) {
        self.faults = Some(faults);
    }

    /// Detach the file from drop-deletion and return its path.
    pub fn keep(mut self) -> PathBuf {
        self.delete_on_drop = false;
        self.path.clone()
    }

    #[inline]
    fn offset(&self, b: usize, r: usize, c: usize) -> u64 {
        (((b * self.h + r) * self.w + c) * 4) as u64
    }

    /// Positioned read: `pread` on unix (no lock, no cursor), a
    /// lock-guarded seek+read elsewhere.
    fn read_at_off(&self, buf: &mut [u8], off: u64) -> std::io::Result<()> {
        self.read_calls.fetch_add(1, Ordering::Relaxed);
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(buf, off)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let _g = lock_recover(&self.io_lock);
            let mut f = &self.file;
            f.seek(SeekFrom::Start(off))?;
            f.read_exact(buf)
        }
    }

    /// Positioned write: `pwrite` on unix, lock-guarded seek+write
    /// elsewhere.
    fn write_at_off(&self, buf: &[u8], off: u64) -> std::io::Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.write_all_at(buf, off)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let _g = lock_recover(&self.io_lock);
            let mut f = &self.file;
            f.seek(SeekFrom::Start(off))?;
            f.write_all(buf)
        }
    }

    /// Commit `rows` (a whole number of carry-corrected rows, absolute
    /// coordinates) of bin `bin` starting at image row `row0`.  Rows of
    /// one plane are contiguous in the Fig. 2 layout, so this is one
    /// sequential write.
    pub fn write_rows(&self, bin: usize, row0: usize, rows: &[f32]) -> Result<()> {
        if bin >= self.bins || rows.is_empty() || rows.len() % self.w != 0 {
            return Err(anyhow!(
                "bad commit: bin {bin}/{} rows len {} (w={})",
                self.bins,
                rows.len(),
                self.w
            ));
        }
        let nrows = rows.len() / self.w;
        if row0 + nrows > self.h {
            return Err(anyhow!("commit rows {row0}+{nrows} past h={}", self.h));
        }
        let mut bytes = lock_recover(&self.write_scratch);
        bytes.clear();
        bytes.reserve(rows.len() * 4);
        for &v in rows.iter() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // Checksum what the caller committed, *then* let the injector
        // corrupt the outgoing buffer: an injected write fault is
        // persistent on disk, so read-side verification must reread,
        // still mismatch, and fail typed.
        {
            let row_bytes = self.w * 4;
            let mut ck = lock_recover(&self.check);
            for r in 0..nrows {
                let idx = bin * self.h + row0 + r;
                ck.sums[idx] = fnv1a32(&bytes[r * row_bytes..(r + 1) * row_bytes]);
                ck.written[idx] = true;
            }
        }
        let mut commit_len = bytes.len();
        if let Some(f) = &self.faults {
            match f.decide(FaultSite::SpillWrite) {
                Some(FaultAction::Corrupt) => {
                    let salt = self.offset(bin, row0, 0) ^ 0xD15C_0000;
                    corrupt_bytes(&mut bytes[..], salt);
                }
                Some(FaultAction::ShortWrite) => {
                    // Torn write: only a prefix reaches disk.  Halving
                    // guarantees at least the final row is missing, so
                    // read-side verification must mismatch, reread the
                    // same truncated bytes, and fail typed.
                    commit_len = bytes.len() / 2;
                }
                _ => {}
            }
        }
        self.write_at_off(&bytes[..commit_len], self.offset(bin, row0, 0))?;
        self.bytes_written.fetch_add(commit_len, Ordering::Relaxed);
        Ok(())
    }

    /// Read `nrows` rows of bin `bin` starting at `row0` into `out`
    /// (length `nrows×w`), verifying each written row's checksum.  A
    /// mismatching row is reread once (transient corruption heals); a
    /// second mismatch returns a typed error rather than wrong data.
    pub fn read_rows(&self, bin: usize, row0: usize, nrows: usize, out: &mut [f32]) -> Result<()> {
        assert_eq!(out.len(), nrows * self.w, "output length mismatch");
        let bytes = self.read_rows_raw(bin, row0, nrows)?;
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }

    /// [`Self::read_rows`] without the f32 decode: the same verified
    /// positioned read, returned as raw little-endian bytes.  This is
    /// the proc plane's strip export — the supervisor copies the bytes
    /// straight into a shm ring slot and the child decodes them in
    /// place, so the strip never takes an f32 round-trip through the
    /// host heap on its way to shared memory.
    pub fn read_rows_raw(&self, bin: usize, row0: usize, nrows: usize) -> Result<Vec<u8>> {
        if bin >= self.bins || row0 + nrows > self.h {
            return Err(anyhow!("read outside tensor"));
        }
        if nrows == 0 {
            return Ok(Vec::new());
        }
        let mut bytes = vec![0u8; nrows * self.w * 4];
        self.read_at_off(&mut bytes, self.offset(bin, row0, 0))?;
        if let Some(f) = &self.faults {
            if f.decide(FaultSite::SpillRead) == Some(FaultAction::Corrupt) {
                // Transient: the file is intact, only this buffer is
                // bad — verification must catch it and the reread heal.
                let salt = self.offset(bin, row0, 0) ^ 0x5EED_0000;
                corrupt_bytes(&mut bytes, salt);
            }
        }
        let row_bytes = self.w * 4;
        {
            let ck = lock_recover(&self.check);
            for r in 0..nrows {
                let idx = bin * self.h + row0 + r;
                if !ck.written[idx] {
                    continue;
                }
                let span = r * row_bytes..(r + 1) * row_bytes;
                if fnv1a32(&bytes[span.clone()]) == ck.sums[idx] {
                    continue;
                }
                self.verify_rereads.fetch_add(1, Ordering::Relaxed);
                self.read_at_off(&mut bytes[span.clone()], self.offset(bin, row0 + r, 0))?;
                if fnv1a32(&bytes[span]) != ck.sums[idx] {
                    self.verify_failures.fetch_add(1, Ordering::Relaxed);
                    return Err(anyhow!(
                        "checksum mismatch: bin {bin} row {} corrupt after reread ({})",
                        row0 + r,
                        self.path.display()
                    ));
                }
            }
        }
        Ok(bytes)
    }

    /// One corner value — a single positioned read; on unix concurrent
    /// queries never contend on a lock.
    fn corner(&self, b: usize, r: usize, c: usize) -> Result<f32> {
        let mut buf = [0u8; 4];
        self.read_at_off(&mut buf, self.offset(b, r, c))?;
        self.corner_reads.fetch_add(1, Ordering::Relaxed);
        Ok(f32::from_le_bytes(buf))
    }

    /// Eq. 2 against the spilled tensor — the batched path: all corner
    /// offsets for all bins are gathered, sorted, merged into
    /// contiguous runs (gap ≤ [`COALESCE_GAP`]) and fetched with **one
    /// positioned read per run** instead of one seek per corner.  The
    /// per-bin arithmetic then runs on the scattered values in exactly
    /// the order of [`Self::query_reference`] /
    /// [`crate::histogram::region::region_histogram`], so results stay
    /// bit-identical (asserted in the tests below and in
    /// `tests/tune_property.rs`) while a `bins`-bin query drops from
    /// `4·bins` syscalls to a handful.
    pub fn query(&self, rect: Rect) -> Result<Vec<f32>> {
        if !rect.fits(self.h, self.w) {
            return Err(anyhow!("rect {rect:?} outside {}x{}", self.h, self.w));
        }
        let (r0, c0, r1, c1) = (rect.r0, rect.c0, rect.r1, rect.c1);
        // Gather the distinct corner coordinates: slot `b*4 + k` with
        // k ∈ {BR, above-TR, left-BL, diag-TL} in Eq. 2 order.
        let mut corners: Vec<(u64, usize)> = Vec::with_capacity(self.bins * 4);
        for b in 0..self.bins {
            corners.push((self.offset(b, r1, c1), b * 4));
            if r0 > 0 {
                corners.push((self.offset(b, r0 - 1, c1), b * 4 + 1));
            }
            if c0 > 0 {
                corners.push((self.offset(b, r1, c0 - 1), b * 4 + 2));
            }
            if r0 > 0 && c0 > 0 {
                corners.push((self.offset(b, r0 - 1, c0 - 1), b * 4 + 3));
            }
        }
        let n_corners = corners.len();
        corners.sort_unstable_by_key(|&(off, _)| off);
        let mut vals = vec![0.0f32; self.bins * 4];
        let mut buf: Vec<u8> = Vec::new();
        let mut i = 0;
        while i < corners.len() {
            let start = corners[i].0;
            let mut end = start + 4;
            let mut j = i + 1;
            while j < corners.len() && corners[j].0 <= end + COALESCE_GAP {
                end = end.max(corners[j].0 + 4);
                j += 1;
            }
            buf.resize((end - start) as usize, 0);
            self.read_at_off(&mut buf, start)?;
            for &(off, slot) in &corners[i..j] {
                let p = (off - start) as usize;
                vals[slot] = f32::from_le_bytes([buf[p], buf[p + 1], buf[p + 2], buf[p + 3]]);
            }
            i = j;
        }
        self.corner_reads.fetch_add(n_corners, Ordering::Relaxed);
        // Eq. 2 per bin — byte-for-byte the reference arithmetic order.
        let mut out = Vec::with_capacity(self.bins);
        for b in 0..self.bins {
            let mut v = vals[b * 4];
            if r0 > 0 {
                v -= vals[b * 4 + 1];
            }
            if c0 > 0 {
                v -= vals[b * 4 + 2];
            }
            if r0 > 0 && c0 > 0 {
                v += vals[b * 4 + 3];
            }
            out.push(v);
        }
        Ok(out)
    }

    /// The unbatched Eq. 2 path — 4 positioned reads per bin — kept as
    /// the oracle [`Self::query`] is bit-identity-tested against.
    pub fn query_reference(&self, rect: Rect) -> Result<Vec<f32>> {
        if !rect.fits(self.h, self.w) {
            return Err(anyhow!("rect {rect:?} outside {}x{}", self.h, self.w));
        }
        let (r0, c0, r1, c1) = (rect.r0, rect.c0, rect.r1, rect.c1);
        let mut out = Vec::with_capacity(self.bins);
        for b in 0..self.bins {
            let mut v = self.corner(b, r1, c1)?;
            if r0 > 0 {
                v -= self.corner(b, r0 - 1, c1)?;
            }
            if c0 > 0 {
                v -= self.corner(b, r1, c0 - 1)?;
            }
            if r0 > 0 && c0 > 0 {
                v += self.corner(b, r0 - 1, c0 - 1)?;
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Batched [`Self::query`].
    pub fn query_batch(&self, rects: &[Rect]) -> Result<Vec<Vec<f32>>> {
        rects.iter().map(|&r| self.query(r)).collect()
    }

    /// Materialize the whole tensor in RAM (tests / small tensors —
    /// defeats the point otherwise).
    pub fn to_histogram(&self) -> Result<IntegralHistogram> {
        let mut ih = IntegralHistogram::zeros(self.bins, self.h, self.w);
        let plane = self.h * self.w;
        for b in 0..self.bins {
            let dst = &mut ih.data[b * plane..(b + 1) * plane];
            self.read_rows(b, 0, self.h, dst)?;
        }
        Ok(ih)
    }

    /// Force written planes to stable storage (`fdatasync`) — call
    /// before handing a [`Self::keep`]-detached file to another
    /// process.
    pub fn flush(&self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }
}

impl Drop for TensorStore {
    fn drop(&mut self) {
        if self.delete_on_drop {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::region::region_histogram;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    /// Spill a computed tensor plane-by-plane (the reassembler's job in
    /// production; done by hand here to isolate the store).
    fn spill_of(ih: &IntegralHistogram) -> TensorStore {
        let store = TensorStore::spill(ih.bins, ih.h, ih.w).expect("spill");
        for b in 0..ih.bins {
            store.write_rows(b, 0, ih.plane(b)).expect("write plane");
        }
        store
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let img = random_image(19, 27, 6, 3);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        assert_eq!(store.bytes_written(), ih.nbytes());
        let back = store.to_histogram().expect("read back");
        assert_eq!(ih.max_abs_diff(&back), 0.0);
    }

    #[test]
    fn queries_match_in_ram_region_lookups() {
        let img = random_image(23, 31, 5, 11);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        let mut rng = Xoshiro256::new(5);
        for _ in 0..40 {
            let r0 = rng.range(0, 23);
            let c0 = rng.range(0, 31);
            let r1 = rng.range(r0, 23);
            let c1 = rng.range(c0, 31);
            let rect = Rect::new(r0, c0, r1, c1);
            assert_eq!(store.query(rect).expect("query"), region_histogram(&ih, rect), "{rect:?}");
        }
        assert!(store.corner_reads() > 0);
    }

    #[test]
    fn batched_query_is_bit_identical_to_reference_and_coalesces() {
        let img = random_image(23, 31, 8, 13);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        let mut rng = Xoshiro256::new(9);
        for _ in 0..50 {
            let r0 = rng.range(0, 23);
            let c0 = rng.range(0, 31);
            let r1 = rng.range(r0, 23);
            let c1 = rng.range(c0, 31);
            let rect = Rect::new(r0, c0, r1, c1);
            let before = store.read_calls();
            let got = store.query(rect).expect("batched query");
            let calls = store.read_calls() - before;
            assert_eq!(got, store.query_reference(rect).expect("reference"), "{rect:?}");
            assert_eq!(got, region_histogram(&ih, rect), "{rect:?}");
            // 8 bins → up to 32 corners; coalescing must beat
            // read-per-corner (this small tensor coalesces to ~1 run).
            assert!((1..32).contains(&calls), "{rect:?}: {calls} reads");
        }
    }

    #[test]
    fn partial_row_commits_compose() {
        let img = random_image(16, 8, 3, 7);
        let ih = integral_histogram_seq(&img);
        let store = TensorStore::spill(3, 16, 8).expect("spill");
        // Commit each plane as two strips in reverse order — offsets,
        // not call order, determine layout.
        for b in 0..3 {
            let plane = ih.plane(b);
            store.write_rows(b, 10, &plane[10 * 8..]).expect("bottom strip");
            store.write_rows(b, 0, &plane[..10 * 8]).expect("top strip");
        }
        let back = store.to_histogram().expect("read back");
        assert_eq!(ih.max_abs_diff(&back), 0.0);
    }

    #[test]
    fn bad_commits_are_rejected() {
        let store = TensorStore::spill(2, 4, 4).expect("spill");
        assert!(store.write_rows(2, 0, &[0.0; 4]).is_err(), "bin out of range");
        assert!(store.write_rows(0, 0, &[0.0; 3]).is_err(), "ragged rows");
        assert!(store.write_rows(0, 3, &[0.0; 8]).is_err(), "past bottom");
        assert!(store.query(Rect::new(0, 0, 4, 4)).is_err(), "rect outside");
    }

    #[test]
    fn raw_strip_export_matches_the_decoded_read() {
        let img = random_image(14, 9, 3, 41);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        let raw = store.read_rows_raw(1, 3, 6).expect("raw strip");
        assert_eq!(raw.len(), 6 * 9 * 4);
        let mut decoded = vec![0.0f32; 6 * 9];
        store.read_rows(1, 3, 6, &mut decoded).expect("decoded strip");
        let reencoded: Vec<u8> = decoded.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(raw, reencoded, "raw export is the same verified bytes");
        assert!(store.read_rows_raw(3, 0, 1).is_err(), "bin out of range");
        assert!(store.read_rows_raw(0, 10, 5).is_err(), "past bottom");
        assert!(store.read_rows_raw(0, 5, 0).expect("empty strip").is_empty());
    }

    #[test]
    fn clean_roundtrip_never_rereads() {
        let img = random_image(12, 9, 4, 21);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        let _ = store.to_histogram().expect("read back");
        assert_eq!(store.verify_rereads(), 0);
        assert_eq!(store.verify_failures(), 0);
    }

    #[test]
    fn on_disk_corruption_is_detected_not_served() {
        use std::io::{Seek, SeekFrom, Write};
        let img = random_image(10, 7, 3, 17);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        // Corrupt one byte on disk behind the store's back — a bad
        // sector.  The reread sees the same bad bytes, so this is the
        // persistent path: typed error, no wrong data.
        let mut f = OpenOptions::new().write(true).open(store.path()).expect("reopen");
        f.seek(SeekFrom::Start(42)).expect("seek");
        let victim = {
            let mut probe = [0u8; 1];
            store.read_at_off(&mut probe, 42).expect("probe");
            probe[0]
        };
        f.write_all(&[victim ^ 0x40]).expect("flip");
        drop(f);
        let err = store.to_histogram().expect_err("corruption must not be served");
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
        assert_eq!(store.verify_rereads(), 1, "exactly one reread before failing");
        assert_eq!(store.verify_failures(), 1);
        // Untouched planes still verify: reads are per-row, so the
        // store remains usable for intact regions.
        let mut row = vec![0.0f32; 7];
        store.read_rows(2, 9, 1, &mut row).expect("intact row still reads");
    }

    #[test]
    fn spill_file_is_deleted_on_drop() {
        let store = TensorStore::spill(1, 2, 2).expect("spill");
        let path = store.path().to_path_buf();
        assert!(path.exists());
        drop(store);
        assert!(!path.exists(), "temp spill must be cleaned up");
    }

    #[test]
    fn open_reads_a_kept_file_without_truncating() {
        let img = random_image(11, 6, 3, 29);
        let ih = integral_histogram_seq(&img);
        let store = spill_of(&ih);
        store.flush().expect("flush");
        let path = store.keep();
        // Reopen (simulating another process) — contents must survive
        // and read back bit-identical; reopened rows are unverified so
        // no rereads fire.
        let back = TensorStore::open(&path, 3, 11, 6).expect("open");
        let got = back.to_histogram().expect("read back");
        assert_eq!(ih.max_abs_diff(&got), 0.0);
        assert_eq!(back.verify_rereads(), 0);
        // Geometry mismatch is a typed error, never a short read.
        assert!(TensorStore::open(&path, 3, 11, 7).is_err(), "length mismatch");
        assert!(TensorStore::open("/nonexistent/x.bin", 1, 1, 1).is_err());
        drop(back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn keep_detaches_the_file() {
        let store = TensorStore::spill(1, 2, 2).expect("spill");
        store.write_rows(0, 0, &[1.0, 2.0, 3.0, 4.0]).expect("write");
        let path = store.keep();
        assert!(path.exists(), "kept file must survive the drop");
        let reopened = TensorStore::create(&path, 1, 2, 2).expect("recreate truncates");
        drop(reopened);
        let _ = std::fs::remove_file(&path);
    }
}
