"""AOT compile path: lower every artifact the Rust runtime needs to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target).  Produces one ``<name>.hlo.txt`` per entry in the
artifact matrix plus ``manifest.json`` describing each artifact's
strategy, geometry and I/O signature — the Rust ``runtime::artifact``
module consumes the manifest.

Python runs exactly once, at build time; the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict, dataclass, field

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Artifact matrix
# ---------------------------------------------------------------------------


@dataclass
class Artifact:
    """One AOT-lowered HLO module plus the metadata Rust needs to run it."""

    name: str
    kind: str  # "strategy" | "init" | "query" | "serve"
    strategy: str
    height: int  # true image height (pre-padding)
    width: int
    padded_h: int
    padded_w: int
    bins: int
    tile: int
    n_rects: int = 0
    file: str = ""
    inputs: list = field(default_factory=list)
    outputs: list = field(default_factory=list)


def _strategy_artifacts(quick: bool) -> list[Artifact]:
    arts: list[Artifact] = []

    def add(strategy, size, bins, tile, true_hw=None):
        h = w = size if isinstance(size, int) else None
        if h is None:
            h, w = size
        th, tw = true_hw if true_hw else (h, w)
        name = f"{strategy}_{th}x{tw}_b{bins}_t{tile}"
        arts.append(
            Artifact(
                name=name,
                kind="strategy",
                strategy=strategy,
                height=th,
                width=tw,
                padded_h=h,
                padded_w=w,
                bins=bins,
                tile=tile,
            )
        )

    if quick:
        for s in model.STRATEGIES:
            add(s, 128, 8, 32)
        return arts

    # Fig. 7 / Fig. 11 / Fig. 19a: the four strategies across image sizes,
    # 32 bins.  CW-B's per-bin unrolled graph is capped at 512² (the paper
    # itself shows it 30× off the chart; see EXPERIMENTS.md).
    for size in (128, 256, 512):
        for s in ("cw_b", "cw_sts", "cw_tis", "wf_tis"):
            tile = 32 if s in ("cw_b", "cw_sts") else 64
            add(s, size, 32, tile)
    for size in (1024,):
        for s in ("cw_sts", "cw_tis", "wf_tis"):
            tile = 32 if s == "cw_sts" else 64
            add(s, size, 32, tile)

    # Fig. 9 / Fig. 10: WF-TiS tile-size sweep at 512²×32.
    for tile in (16, 32):
        add("wf_tis", 512, 32, tile)

    # Fig. 15c,d / Fig. 19b: bins sweep at 512².
    for bins in (16, 64, 128):
        add("wf_tis", 512, bins, 64)

    # Fig. 20: standard 640×480, 32 bins (divisible by tile 32).
    add("wf_tis", (480, 640), 32, 32)

    # Fig. 13 / Fig. 15a,b: HD frames (1280×720 padded to 1280×768).
    for bins in (16, 32):
        add("wf_tis", (768, 1280), bins, 64, true_hw=(720, 1280))

    # Fig. 16/17 large-image path runs per-bin-group: a single-bin-group
    # WF-TiS artifact reused by the multi-device task queue (8 bins/task).
    add("wf_tis", 512, 8, 64)
    add("wf_tis", (768, 1280), 8, 64, true_hw=(720, 1280))
    return arts


def _aux_artifacts(quick: bool) -> list[Artifact]:
    arts = []
    size, bins, tile = (128, 8, 32) if quick else (512, 32, 64)
    arts.append(
        Artifact(
            name=f"init_only_{size}x{size}_b{bins}_t{tile}",
            kind="init",
            strategy="init_only",
            height=size,
            width=size,
            padded_h=size,
            padded_w=size,
            bins=bins,
            tile=tile,
        )
    )
    n_rects = 64
    arts.append(
        Artifact(
            name=f"region_query_{size}x{size}_b{bins}_n{n_rects}",
            kind="query",
            strategy="region_query",
            height=size,
            width=size,
            padded_h=size,
            padded_w=size,
            bins=bins,
            tile=tile,
            n_rects=n_rects,
        )
    )
    arts.append(
        Artifact(
            name=f"serve_{size}x{size}_b{bins}_t{tile}_n{n_rects}",
            kind="serve",
            strategy="wf_tis_with_query",
            height=size,
            width=size,
            padded_h=size,
            padded_w=size,
            bins=bins,
            tile=tile,
            n_rects=n_rects,
        )
    )
    return arts


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def set_signature(art: Artifact) -> None:
    """Record the artifact's I/O signature (always, even when cached)."""
    image = {"name": "image", "dtype": "i32", "shape": [art.padded_h, art.padded_w]}
    ih = {"name": "ih", "dtype": "f32", "shape": [art.bins, art.padded_h, art.padded_w]}
    rects = {"name": "rects", "dtype": "i32", "shape": [art.n_rects, 4]}
    hists = {"name": "hists", "dtype": "f32", "shape": [art.n_rects, art.bins]}
    if art.kind in ("strategy", "init"):
        art.inputs, art.outputs = [image], [ih]
    elif art.kind == "query":
        art.inputs, art.outputs = [ih, rects], [hists]
    elif art.kind == "serve":
        art.inputs, art.outputs = [image, rects], [ih, hists]
    else:
        raise ValueError(art.kind)


def lower_artifact(art: Artifact) -> str:
    img_spec = jax.ShapeDtypeStruct((art.padded_h, art.padded_w), jnp.int32)
    if art.kind in ("strategy", "init"):
        fn = model.STRATEGIES.get(art.strategy, None) or getattr(model, art.strategy)
        lowered = jax.jit(lambda img: (fn(img, art.bins, art.tile),)).lower(img_spec)
    elif art.kind == "query":
        ih_spec = jax.ShapeDtypeStruct((art.bins, art.padded_h, art.padded_w), jnp.float32)
        rects_spec = jax.ShapeDtypeStruct((art.n_rects, 4), jnp.int32)
        lowered = jax.jit(lambda ih, rects: (model.region_query(ih, rects),)).lower(
            ih_spec, rects_spec
        )
    elif art.kind == "serve":
        rects_spec = jax.ShapeDtypeStruct((art.n_rects, 4), jnp.int32)
        lowered = jax.jit(
            lambda img, rects: model.wf_tis_with_query(img, rects, art.bins, art.tile)
        ).lower(img_spec, rects_spec)
    else:
        raise ValueError(art.kind)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--profile",
        default=os.environ.get("AOT_PROFILE", "full"),
        choices=("quick", "full"),
        help="quick = tiny artifact set for CI smoke tests",
    )
    ap.add_argument("--force", action="store_true", help="re-lower even if the file exists")
    args = ap.parse_args()

    quick = args.profile == "quick"
    os.makedirs(args.out_dir, exist_ok=True)
    artifacts = _strategy_artifacts(quick) + _aux_artifacts(quick)

    manifest = []
    for art in artifacts:
        art.file = f"{art.name}.hlo.txt"
        set_signature(art)
        path = os.path.join(args.out_dir, art.file)
        if os.path.exists(path) and not args.force:
            print(f"kept    {art.name}")
        else:
            text = lower_artifact(art)
            with open(path, "w") as f:
                f.write(text)
            print(f"lowered {art.name}: {len(text)} chars")
        manifest.append(asdict(art))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"profile": args.profile, "artifacts": manifest}, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
