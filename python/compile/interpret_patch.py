"""Performance patch for Pallas interpret-mode lowering (jax 0.8.x).

Why this exists
---------------
``pallas_call(..., interpret=True)`` lowers the kernel grid to an HLO while
loop.  The stock interpreter (``jax._src.pallas.hlo_interpreter.
pallas_call_hlo_interpret``) writes *every* carried block back with a
``dynamic_update_slice`` on *every* grid step — including blocks of
read-only inputs the kernel never mutates.  XLA then sees each input
buffer both read (dynamic-slice) and written (DUS) inside the loop body
and materializes a full copy of the buffer per iteration.  For the
integral-histogram kernels that turns an O(h·w·b) pass into an
O(h·w·b · n_tiles) one: the tiled h-scan of a 32×256×256 tensor measured
~834 ms instead of ~15 ms (see EXPERIMENTS.md §Perf).

The patch below is a copy of the upstream function with one change:
blocks whose discharged-jaxpr output variable *is* the corresponding
input variable (i.e. the kernel body never stores to that ref) are not
written back, so XLA keeps the input buffer read-only and copy-free.
Detection is static (jaxpr variable identity), so a kernel that does
write an input ref falls back to the stock behaviour — correctness is
never at risk, and the pytest suite runs entirely on the patched path.

Apply with ``interpret_patch.apply()`` (done on ``compile.kernels``
import, so both the test suite and the AOT pipeline use it).
"""

from __future__ import annotations

import itertools
from functools import reduce

import jax.numpy as jnp
from jax import lax
from jax._src import core as jax_core
from jax._src.pallas import core as pallas_core
from jax._src.pallas import hlo_interpreter as hi
from jax._src.pallas import primitives
from jax._src.util import split_list
from jax._src.lax.control_flow import loops
from jax._src.lax import slicing

_ORIGINAL = hi.pallas_call_hlo_interpret
_APPLIED = False


def _written_block_mask(
    discharged_jaxpr, num_scalars: int, num_index: int, num_inout: int
) -> list[bool]:
    """True for inout blocks the kernel body actually stores to.

    The state-discharge pass forwards an unmodified Ref as the same jaxpr
    Var; a mutated Ref comes back as a fresh Var.  Anything we cannot
    prove unwritten is treated as written (safe fallback).
    """
    invars = discharged_jaxpr.invars
    outvars = discharged_jaxpr.outvars
    mask = []
    for i in range(num_inout):
        try:
            inv = invars[num_scalars + i]
            outv = outvars[num_index + i]
        except IndexError:  # pragma: no cover - defensive
            mask.append(True)
            continue
        mask.append(outv is not inv)
    return mask


def pallas_call_hlo_interpret_patched(
    *args,
    backend,
    jaxpr,
    debug,
    input_output_aliases,
    grid_mapping,
    mesh,
    compiler_params,
    cost_estimate,
    out_avals,
    metadata,
    name,
):
    del mesh, compiler_params, cost_estimate, out_avals, metadata, name
    debug_info = jaxpr.debug_info
    dynamic_grid_args, args = split_list(args, [grid_mapping.num_dynamic_grid_bounds])
    dynamic_grid_args_iter = iter(dynamic_grid_args)
    grid = tuple(
        a if a is not pallas_core.dynamic_grid_dim else next(dynamic_grid_args_iter)
        for a in grid_mapping.grid
    )
    assert next(dynamic_grid_args_iter, None) is None
    discharged_jaxpr, discharged_consts, scratch_avals = hi.kernel_to_hlo_jaxpr(
        jaxpr, (), grid_mapping, backend=backend
    )
    if debug:
        print(f"\nJaxpr of the kernel in pallas_call {debug_info.func_src_info}:")
        print(discharged_jaxpr)
    out = hi._initialize_output_vals(
        grid_mapping.block_mappings_output, args, input_output_aliases
    )
    scalars = args[grid_mapping.slice_index_ops]
    block_args = args[len(scalars):]
    scratch_values = tuple(
        primitives.uninitialized_value(a.shape, a.dtype) for a in scratch_avals
    )

    carry = []
    for x, bm in zip(itertools.chain(block_args, out), grid_mapping.block_mappings):
        padding = [
            bd.padding if isinstance(bd, pallas_core.Element) else (0, 0)
            for bd in bm.block_shape
        ]
        if padding is not None and any(p != (0, 0) for p in padding):
            if input_output_aliases:
                raise NotImplementedError("Padding with aliasing not supported.")
            pad_value = primitives.uninitialized_value(shape=(), dtype=x.dtype)
            x = lax.pad(x, pad_value, [(*p, 0) for p in padding])
        carry.append(x)

    block_shapes = [
        pallas_core._get_block_shape(bm.block_shape) for bm in grid_mapping.block_mappings
    ]
    is_squeeze_dim = [
        tuple(isinstance(bd, pallas_core.Squeezed) for bd in bm.block_shape)
        for bm in grid_mapping.block_mappings
    ]

    carry = list(map(hi._pad_to_block_dimension, carry, block_shapes))
    carry.extend(scratch_values)

    num_inout_blocks = len(block_args) + len(out)
    # --- patch: statically determine which blocks the kernel writes ---
    written = _written_block_mask(
        discharged_jaxpr, len(scalars), grid_mapping.num_index_operands, num_inout_blocks
    )
    # Blocks that feed an output (or alias one) must always be written back.
    for k in range(len(block_args), num_inout_blocks):
        written[k] = True
    for in_idx, _ in (input_output_aliases or ()):
        written[in_idx] = True
    # -------------------------------------------------------------------

    grid_start_indices = (jnp.int32(0),) * len(grid)
    if grid:
        num_iterations = reduce(jnp.multiply, grid)  # type: ignore[arg-type]
    else:
        num_iterations = 1

    def cond(carry):
        i, *_ = carry
        return i < num_iterations

    def body(carry):
        i, loop_idx, *carry_blocks = carry
        if grid_mapping.local_grid_env is not None:
            local_grid_env = grid_mapping.local_grid_env(loop_idx, grid)
        else:
            local_grid_env = tuple(
                pallas_core.GridAxis(idx, b)
                for dim, (idx, b) in enumerate(zip(loop_idx, grid))
                if dim not in grid_mapping.vmapped_dims
            )
        carry_consts_ins, scratch = split_list(carry_blocks, [num_inout_blocks])
        with pallas_core.grid_env(local_grid_env):
            for s in scalars:
                if isinstance(s.dtype, jax_core.bint):
                    aval = jax_core.get_aval(s)
                    s.aval = aval.update(dtype=jnp.int32)
            start_indices = [
                bm.compute_start_indices_interpret(loop_idx, *scalars)
                for bm in grid_mapping.block_mappings
            ]
        blocks = map(
            hi._dynamic_slice, start_indices, block_shapes, carry_consts_ins, is_squeeze_dim
        )
        with pallas_core.grid_env(local_grid_env):
            blocks = jax_core.eval_jaxpr(
                discharged_jaxpr, discharged_consts, *scalars, *blocks, *scratch
            )
        _, out_inout, out_scratch = split_list(
            blocks, [grid_mapping.num_index_operands, num_inout_blocks]
        )
        # --- patch: only write back blocks the kernel actually stores to ---
        out_carry = [
            hi._dynamic_update_slice(si, bs, carry_el, blk, sq) if wr else carry_el
            for si, bs, carry_el, blk, sq, wr in zip(
                start_indices, block_shapes, carry_consts_ins, out_inout, is_squeeze_dim, written
            )
        ]
        # --------------------------------------------------------------------
        return (i + 1, hi._get_next_indices(grid, loop_idx), *out_carry, *out_scratch)

    (_, _, *carry) = loops.while_loop(cond, body, (jnp.int32(0), grid_start_indices, *carry))

    out_out = carry[len(block_args):len(block_args) + len(out)]
    out_nopad = []
    for o, bm in zip(out_out, grid_mapping.block_mappings_output):
        padding = [
            bd.padding if isinstance(bd, pallas_core.Element) else (0, 0)
            for bd in bm.block_shape
        ]
        if padding is not None and any(p != (0, 0) for p in padding):
            if input_output_aliases:
                raise NotImplementedError("Padding with aliasing not supported.")
            pad_low, pad_high = zip(*padding)
            limit_indices = [s - p for s, p in zip(o.shape, pad_high)]
            o = slicing.slice(o, pad_low, limit_indices)
        if o.shape != bm.array_aval.shape:
            o = slicing.slice(o, (0,) * o.ndim, bm.array_aval.shape)
        out_nopad.append(o)
    return out_nopad


def apply() -> None:
    """Install the patched interpreter (idempotent)."""
    global _APPLIED
    if not _APPLIED:
        hi.pallas_call_hlo_interpret = pallas_call_hlo_interpret_patched
        _APPLIED = True


def remove() -> None:
    """Restore the stock interpreter (used by the patch's own tests)."""
    global _APPLIED
    hi.pallas_call_hlo_interpret = _ORIGINAL
    _APPLIED = False
