"""Tiled binning kernel — the Q function of Eq. 1 as a Pallas kernel.

The paper initializes the integral histogram tensor on the GPU
(``IH(I(x,y), x, y) ← 1`` in Algorithms 2–5) because transferring a
pre-initialized b×h×w tensor over PCIe is slower than shipping the h×w
image and scattering on-device.  This kernel is that initialization step:
each grid step stages one image tile into VMEM and writes the one-hot
indicator plane for one bin.

Grid: (bins, h/tile, w/tile).  The image block index map ignores the bin
coordinate, so the same tile is revisited once per bin — mirroring the
paper's bin-parallel scheme where every bin's plane reads the image
independently (and letting the L2 strategies fuse or split binning freely).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 64


def _binning_kernel(img_ref, out_ref):
    b = pl.program_id(0)
    tile = img_ref[0]
    out_ref[0] = (tile == b).astype(jnp.float32)


@functools.partial(jax.jit, static_argnums=(1, 2))
def binning(image: jnp.ndarray, bins: int, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """One-hot bin planes, tiled through VMEM.

    ``image``: int32 (h, w) of bin indices; h and w must be multiples of
    ``tile`` (the L2 layer pads, matching the paper's padding note in
    §3.4).  Returns f32 (bins, h, w).
    """
    h, w = image.shape
    if h % tile or w % tile:
        raise ValueError(f"image {h}x{w} not divisible by tile {tile}")
    grid = (bins, h // tile, w // tile)
    return pl.pallas_call(
        _binning_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile, tile), lambda b, i, j: (0, i, j))],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((bins, h, w), jnp.float32),
        interpret=True,
    )(image[None])
