"""Tiled 2-D / 3-D transpose — the CUDA-SDK transpose kernel.

CW-B transposes each bin plane separately (Algorithm 2, line 8); CW-STS
upgrades it to a single 3-D transpose over the whole b×h×w tensor by
folding the bin offset into the indexing (§3.3, Fig. 4).  On the GPU the
kernel stages BLOCK_DIM×BLOCK_DIM tiles through shared memory with +1
padding to avoid bank conflicts; in the TPU/VMEM model the staging is the
BlockSpec itself and banking does not apply (DESIGN.md
§Hardware-Adaptation), so the kernel body is just the in-VMEM transpose of
one tile written back to the swapped block coordinate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper sets BLOCK_DIM to the shared-memory bank count (32); we keep
# the same default tile edge for the lowered artifacts.
BLOCK_DIM = 32


def _transpose2d_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnums=(1,))
def transpose2d(x: jnp.ndarray, tile: int = BLOCK_DIM) -> jnp.ndarray:
    """Tiled transpose of a 2-D array (h, w) → (w, h)."""
    h, w = x.shape
    if h % tile or w % tile:
        raise ValueError(f"array {h}x{w} not divisible by tile {tile}")
    return pl.pallas_call(
        _transpose2d_kernel,
        grid=(h // tile, w // tile),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((w, h), jnp.float32),
        interpret=True,
    )(x)


def _transpose3d_kernel(x_ref, o_ref):
    o_ref[0] = x_ref[0].T


@functools.partial(jax.jit, static_argnums=(1,))
def transpose3d(x: jnp.ndarray, tile: int = BLOCK_DIM) -> jnp.ndarray:
    """Tiled per-bin transpose of a 3-D tensor (b, h, w) → (b, w, h).

    This is the CW-STS 3-D transpose: one kernel launch over a grid of
    (b, w/tile, h/tile) blocks, with the bin offset folded into the block
    index map exactly as §3.3 folds it into the CUDA indexing.
    """
    b, h, w = x.shape
    if h % tile or w % tile:
        raise ValueError(f"tensor {b}x{h}x{w} not divisible by tile {tile}")
    return pl.pallas_call(
        _transpose3d_kernel,
        grid=(b, h // tile, w // tile),
        in_specs=[pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, i, j))],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, j, i)),
        out_shape=jax.ShapeDtypeStruct((b, w, h), jnp.float32),
        interpret=True,
    )(x)
