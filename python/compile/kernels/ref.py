"""Pure-jnp correctness oracle for the integral histogram.

This module is the ground truth every Pallas kernel and every strategy in
``model.py`` is validated against (pytest + hypothesis sweeps in
``python/tests/``).  It implements the paper's Eq. 1 directly:

    H(b, x, y) = sum_{r<=x, c<=y} Q(I(r,c), b)

with the *inclusive* convention used by Algorithm 1 (the histogram at
(x, y) includes pixel (x, y) itself).  Region queries implement Eq. 2.
"""

from __future__ import annotations

import jax.numpy as jnp


def binning(image: jnp.ndarray, bins: int) -> jnp.ndarray:
    """Q function of Eq. 1: one-hot bin indicator tensor.

    ``image`` is an integer array of shape (h, w) whose values are already
    bin indices in [0, bins).  Returns f32 of shape (bins, h, w) where
    plane b is 1.0 where ``image == b``.
    """
    return (image[None, :, :] == jnp.arange(bins, dtype=image.dtype)[:, None, None]).astype(
        jnp.float32
    )


def quantize(image: jnp.ndarray, bins: int, levels: int = 256) -> jnp.ndarray:
    """Map raw intensities in [0, levels) to bin indices in [0, bins)."""
    return (image.astype(jnp.int32) * bins) // levels


def integral_histogram(image: jnp.ndarray, bins: int) -> jnp.ndarray:
    """Reference integral histogram: double inclusive cumsum of the one-hot.

    Shape (bins, h, w) f32.  This is Algorithm 1 written as two scans.
    """
    q = binning(image, bins)
    return jnp.cumsum(jnp.cumsum(q, axis=1), axis=2)


def region_histogram(ih: jnp.ndarray, r0: int, c0: int, r1: int, c1: int) -> jnp.ndarray:
    """Eq. 2: histogram of the inclusive rectangle [r0..r1] x [c0..c1].

    Uses the inclusive-integral convention: the subtracted corners are just
    outside the region, guarded at the image border.
    """
    h = ih[:, r1, c1]
    if r0 > 0:
        h = h - ih[:, r0 - 1, c1]
    if c0 > 0:
        h = h - ih[:, r1, c0 - 1]
    if r0 > 0 and c0 > 0:
        h = h + ih[:, r0 - 1, c0 - 1]
    return h


def region_histogram_batch(ih: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """Vectorized Eq. 2 for a batch of rectangles.

    ``rects`` is int32 (n, 4) rows (r0, c0, r1, c1), inclusive coordinates.
    Returns (n, bins).  Implemented with a zero-padded integral histogram so
    the border guards become plain indexing (this is also exactly what the
    lowered HLO artifact does — keep in sync with model.region_query).
    """
    padded = jnp.pad(ih, ((0, 0), (1, 0), (1, 0)))
    r0, c0, r1, c1 = rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3]
    # padded[r+1, c+1] == ih[r, c]; padded[r0, ...] is the exclusive corner.
    a = padded[:, r1 + 1, c1 + 1]
    b = padded[:, r0, c1 + 1]
    c = padded[:, r1 + 1, c0]
    d = padded[:, r0, c0]
    return (a - b - c + d).T
