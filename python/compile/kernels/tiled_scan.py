"""CW-TiS strip-scan kernels (Algorithm 4, Fig. 5).

The cross-weave tiled scan removes both the SDK prescan (work-inefficient,
Eq. 4) and the transpose (pure data movement) by writing *custom* scan
kernels that sweep tiles strip-wise:

  * horizontal pass — vertical strips of width TILE are processed left to
    right; within a strip every (bin, tile-row) pair is independent.  Each
    tile is staged into VMEM, cumsum'd along rows, and the tile's right
    edge is carried to the next strip.
  * vertical pass — horizontal strips top to bottom, carrying the bottom
    edge.

On the GPU the carry lives in global memory between kernel launches; here
it lives in VMEM scratch that persists across the sequential Pallas grid
(DESIGN.md §Hardware-Adaptation).  The grid is ordered so the strip
coordinate is innermost: tile (b, i, j) runs right after (b, i, j−1),
which is the same producer→consumer order the strip-wise launches enforce
on the GPU.

The drawback the paper calls out — and fixes with WF-TiS — is preserved:
the two passes each read AND write the full b×h×w tensor through
VMEM/global memory, i.e. 2× the traffic of the fused wavefront kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .scan_ops import tile_cumsum

DEFAULT_TILE = 64


def _hscan_kernel(x_ref, o_ref, carry_ref):
    """Horizontal tiled scan: inclusive row cumsum with carried left edge."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    tile = x_ref[0]
    h = tile_cumsum(tile, 1) + carry_ref[...][:, None]
    carry_ref[...] = h[:, -1]
    o_ref[0] = h


def _vscan_kernel(x_ref, o_ref, carry_ref):
    """Vertical tiled scan: inclusive column cumsum with carried top edge."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    tile = x_ref[0]
    v = tile_cumsum(tile, 0) + carry_ref[...][None, :]
    carry_ref[...] = v[-1, :]
    o_ref[0] = v


@functools.partial(jax.jit, static_argnums=(1,))
def tiled_hscan(q: jnp.ndarray, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Row-wise inclusive scan of every bin plane, tile-by-tile.

    ``q``: f32 (b, h, w) one-hot planes; h, w divisible by ``tile``.
    Grid (b, h/tile, w/tile) with the strip index j innermost.
    """
    b, h, w = q.shape
    if h % tile or w % tile:
        raise ValueError(f"tensor {b}x{h}x{w} not divisible by tile {tile}")
    return pl.pallas_call(
        _hscan_kernel,
        grid=(b, h // tile, w // tile),
        in_specs=[pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, i, j))],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile,), jnp.float32)],
        interpret=True,
    )(q)


@functools.partial(jax.jit, static_argnums=(1,))
def tiled_vscan(x: jnp.ndarray, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Column-wise inclusive scan of every bin plane, tile-by-tile.

    Grid (b, w/tile, h/tile): the tile-row index i is innermost so each
    column strip is swept top to bottom with the bottom-edge carry.
    """
    b, h, w = x.shape
    if h % tile or w % tile:
        raise ValueError(f"tensor {b}x{h}x{w} not divisible by tile {tile}")
    return pl.pallas_call(
        _vscan_kernel,
        grid=(b, w // tile, h // tile),
        in_specs=[pl.BlockSpec((1, tile, tile), lambda b, j, i: (b, i, j))],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b, j, i: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile,), jnp.float32)],
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnums=(1, 2))
def cw_tis(image: jnp.ndarray, bins: int, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Full CW-TiS strategy: binning → tiled h-scan → tiled v-scan."""
    from . import binning as _binning

    q = _binning.binning(image, bins, tile)
    return tiled_vscan(tiled_hscan(q, tile), tile)
