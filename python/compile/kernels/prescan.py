"""Blelloch work-parallel exclusive prescan — the CUDA-SDK scan kernel.

CW-B and CW-STS (Algorithms 2 and 3) reuse the NVIDIA SDK's all-prefix-sums
kernel [Harris et al., GPU Gems 3].  This module reproduces that kernel's
*structure* in Pallas: an up-sweep (reduce) phase that builds a balanced
binary tree followed by a down-sweep phase that distributes partial sums,
2·log2(n) steps in total (Fig. 3 of the paper).

On SIMT hardware every step schedules all n lanes and masks the inactive
ones, which is where the paper's Eq. 4 efficiency bound 3(n−1)/(n·log n)
comes from.  We keep that shape deliberately: each step does an O(n)
masked update (roll + where over the whole row block), so the lowered HLO
performs the same n·log n work the SDK kernel does — this is what makes
CW-B/CW-STS measurably slower than the custom CW-TiS/WF-TiS kernels, on
our substrate exactly as on the GPU.

The kernel scans each row of a 2-D block independently; row length must be
a power of two (callers pad, as the SDK kernel does).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS_PER_BLOCK = 8


def _log2(n: int) -> int:
    if n & (n - 1):
        raise ValueError(f"prescan length {n} is not a power of two")
    return n.bit_length() - 1


def _blelloch_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Exclusive Blelloch scan of every row of x (rows, n), n a power of 2."""
    n = x.shape[-1]
    steps = _log2(n)
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, len(x.shape) - 1)

    # Up-sweep / reduce: for d in [0, steps): x[k] += x[k - 2^d] at every
    # k ≡ 2^(d+1)-1 (mod 2^(d+1)).  All lanes compute, inactive ones masked
    # — the SIMT execution model of Fig. 3 (top).
    for d in range(steps):
        stride = 1 << (d + 1)
        half = 1 << d
        is_k = (iota + 1) % stride == 0
        from_left = jnp.roll(x, half, axis=-1)
        x = jnp.where(is_k, x + from_left, x)

    # Clear the root, then down-sweep: swap-and-accumulate from root to
    # leaves (Fig. 3, bottom).
    x = jnp.where(iota == n - 1, 0.0, x)
    for d in range(steps - 1, -1, -1):
        stride = 1 << (d + 1)
        half = 1 << d
        is_k = (iota + 1) % stride == 0
        is_j = jnp.roll(is_k, -half, axis=-1)  # positions k - half
        from_right = jnp.roll(x, -half, axis=-1)  # x[k] seen from k - half
        from_left = jnp.roll(x, half, axis=-1)  # x[k - half] seen from k
        x = jnp.where(is_j, from_right, jnp.where(is_k, x + from_left, x))
    return x


def _prescan_kernel(x_ref, o_ref):
    o_ref[...] = _blelloch_rows(x_ref[...])


@functools.partial(jax.jit, static_argnums=(1,))
def prescan_rows(x: jnp.ndarray, rows_per_block: int = DEFAULT_ROWS_PER_BLOCK) -> jnp.ndarray:
    """Exclusive scan of every row of a 2-D array via the Blelloch kernel.

    ``x``: f32 (rows, n); n must be a power of two and rows divisible by
    ``rows_per_block``.  One grid step scans ``rows_per_block`` rows staged
    in VMEM — the analogue of one SDK thread-block scanning one array
    segment in shared memory.
    """
    rows, n = x.shape
    if rows % rows_per_block:
        raise ValueError(f"{rows} rows not divisible by block of {rows_per_block}")
    _log2(n)  # validate power of two
    return pl.pallas_call(
        _prescan_kernel,
        grid=(rows // rows_per_block,),
        in_specs=[pl.BlockSpec((rows_per_block, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_per_block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=True,
    )(x)


def next_pow2(n: int) -> int:
    """Smallest power of two ≥ n (SDK-kernel padding rule)."""
    return 1 << (n - 1).bit_length()


def inclusive_scan_rows(x: jnp.ndarray, rows_per_block: int = DEFAULT_ROWS_PER_BLOCK) -> jnp.ndarray:
    """Inclusive row scan built on the exclusive prescan (pad → scan → add).

    Accepts any row length; pads to the next power of two like the SDK
    wrapper, then converts exclusive → inclusive by adding the input back.
    """
    rows, n = x.shape
    n2 = next_pow2(n)
    if n2 != n:
        x_padded = jnp.pad(x, ((0, 0), (0, n2 - n)))
    else:
        x_padded = x
    ex = prescan_rows(x_padded, rows_per_block)[:, :n]
    return ex + x
