"""WF-TiS — wave-front tiled scan, the paper's fastest kernel (Algorithm 5).

A single fused kernel computes binning, horizontal scan and vertical scan
per tile, so the b×h×w tensor crosses the global-memory boundary exactly
once in each direction (§3.5) — versus twice for CW-TiS and four times
plus transposes for CW-STS.  The data-dependence pattern is the
Needleman–Wunsch wavefront: tile (i, j) needs the right edge of (i, j−1)
after *horizontal* scan and the bottom edge of (i−1, j) after *vertical*
scan.  The paper's "tricky part" — preserving each tile's post-horizontal
last column before the vertical scan overwrites it — maps here to the
``colc`` scratch carry, and the h-element global array for the row carry
maps to the ``rowc`` scratch of width w.

Scheduling: on the GPU, anti-diagonal strips of tiles run concurrently
(Fig. 6).  The Pallas grid on a single core is sequential in row-major
order, which is a linear extension of the wavefront partial order — every
dependency is produced before it is consumed, and the single-pass memory
traffic (the actual source of the speedup) is identical.  Cross-tile
parallelism is recovered one level up: bins are the outer grid dimension
here and are spread across devices by the L3 task queue (DESIGN.md
§Hardware-Adaptation).

Grid: (bins, h/tile, w/tile); image tile is re-read once per bin exactly
as every GPU thread-block re-reads its image tile per bin plane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .scan_ops import tile_cumsum

DEFAULT_TILE = 64


def _wavefront_kernel(img_ref, o_ref, colc_ref, rowc_ref):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    t = o_ref.shape[1]

    # Binning fused into the scan kernel: the IH initialization of
    # Algorithm 5 line 1, for this tile and bin.
    tile = img_ref[0]
    q = (tile == b).astype(jnp.float32)

    # Horizontal scan with the carried right edge of tile (i, j-1).
    @pl.when(j == 0)
    def _():
        colc_ref[...] = jnp.zeros_like(colc_ref)

    h = tile_cumsum(q, 1) + colc_ref[...][:, None]
    # Preserve the post-horizontal last column for tile (i, j+1) BEFORE
    # the vertical scan overwrites the tile — the paper's extra h-element
    # buffer in global memory.
    colc_ref[...] = h[:, -1]

    # Vertical scan with the carried bottom edge of tile (i-1, j).
    @pl.when(i == 0)
    def _():
        rowc_ref[pl.ds(j * t, t)] = jnp.zeros((t,), jnp.float32)

    v = tile_cumsum(h, 0) + rowc_ref[pl.ds(j * t, t)][None, :]
    rowc_ref[pl.ds(j * t, t)] = v[-1, :]
    o_ref[0] = v


@functools.partial(jax.jit, static_argnums=(1, 2))
def wf_tis(image: jnp.ndarray, bins: int, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Full WF-TiS strategy in one Pallas call.

    ``image``: int32 (h, w) of bin indices, h and w divisible by ``tile``.
    Returns the f32 (bins, h, w) integral histogram.
    """
    h, w = image.shape
    if h % tile or w % tile:
        raise ValueError(f"image {h}x{w} not divisible by tile {tile}")
    return pl.pallas_call(
        _wavefront_kernel,
        grid=(bins, h // tile, w // tile),
        in_specs=[pl.BlockSpec((1, tile, tile), lambda b, i, j: (0, i, j))],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((bins, h, w), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile,), jnp.float32),  # colc: right edge carry
            pltpu.VMEM((w,), jnp.float32),  # rowc: bottom edge carries per strip
        ],
        interpret=True,
    )(image[None])


def vmem_bytes(tile: int, w: int) -> int:
    """Static VMEM footprint of one grid step (for the DESIGN.md §6 model).

    image tile (int32) + output tile (f32) + colc + rowc scratch.
    """
    return tile * tile * 4 + tile * tile * 4 + tile * 4 + w * 4
