"""In-tile scan primitives shared by the custom kernels.

``jnp.cumsum`` lowers to a reduce-window op whose CPU implementation in
the runtime's XLA (xla_extension 0.5.1, behind the published ``xla``
crate) is O(n) *per element* — an O(n²)-per-row scan that made larger
tiles slower at execution time, inverting the paper's Fig. 10 tuning
result (see EXPERIMENTS.md §Perf, L1 iteration 2).

``tile_cumsum`` instead emits a Hillis–Steele scan: log2(n) steps of
shift-and-add over the whole tile, each a plain pad/slice/add that XLA
vectorizes.  Work is O(n log n) element ops but fully data-parallel —
the same trade the paper's GPU kernels make inside a thread block — and
on both the modern jaxlib CPU and the 0.5.1 runtime it is strictly
faster than reduce-window for our tile sizes.
"""

from __future__ import annotations

import jax.numpy as jnp


def tile_cumsum(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Inclusive scan along ``axis`` via log-step shift-and-add.

    Requires the scanned extent to be a power of two (kernel tiles are
    16/32/64); falls back to ``jnp.cumsum`` otherwise so the kernels
    stay correct for exotic tile sizes.
    """
    n = x.shape[axis]
    if n & (n - 1):
        return jnp.cumsum(x, axis=axis)
    d = 1
    while d < n:
        x = x + _shift_right(x, d, axis)
        d *= 2
    return x


def _shift_right(x: jnp.ndarray, by: int, axis: int) -> jnp.ndarray:
    """Shift ``x`` by ``by`` positions along ``axis``, zero-filling."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (by, 0)
    padded = jnp.pad(x, pad)
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, x.shape[axis])
    return padded[tuple(idx)]
