"""Layer-1 Pallas kernels for the integral histogram.

Every kernel is written for the TPU memory model (tiles staged through
VMEM via BlockSpec, boundary carries in VMEM scratch) and lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend —
including the Rust CPU client on the request path.  See DESIGN.md
§Hardware-Adaptation for the CUDA→TPU mapping.

Modules
-------
binning     Q function: image → one-hot bin planes (tiled).
prescan     Blelloch up-/down-sweep exclusive scan — the CUDA-SDK kernel
            that CW-B and CW-STS reuse (deliberately work-inefficient).
transpose   Tiled 2-D/3-D transpose (the CUDA-SDK transpose kernel).
tiled_scan  CW-TiS strip kernels: tiled horizontal / vertical scans.
wavefront   WF-TiS: the fused single-pass wavefront tiled scan.
ref         Pure-jnp oracle all of the above are tested against.
"""

from .. import interpret_patch

interpret_patch.apply()

from . import binning, prescan, ref, tiled_scan, transpose, wavefront  # noqa: F401,E402
