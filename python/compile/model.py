"""Layer-2: the four integral-histogram strategies as jax graphs.

Each strategy is a function ``image[int32 h×w] → IH[f32 b×h×w]`` composed
from the Layer-1 Pallas kernels, mirroring Algorithms 2–5 of the paper.
``aot.py`` lowers each (strategy, h, w, bins) instance to HLO text that
the Rust runtime loads via PJRT; nothing in this module ever runs on the
request path.

Strategy inventory (paper §3):

  cw_b    Algorithm 2 — per-bin Blelloch prescans + per-bin 2-D tiled
          transposes.  Many small kernel bodies, SDK-style scans: the
          deliberately naive baseline.
  cw_sts  Algorithm 3 — ONE big prescan over all (b·h) rows, one 3-D
          transpose, one big prescan over all (b·w) rows, transpose back.
  cw_tis  Algorithm 4 — custom tiled horizontal + vertical strip scans,
          no transpose, no Blelloch inefficiency.
  wf_tis  Algorithm 5 — single fused wavefront kernel, one read + one
          write of the tensor.

Also exported for AOT: ``init_only`` (binning alone — the "init" bar of
the paper's Fig. 8 breakdown) and ``region_query`` (Eq. 2 as a batched
lookup graph, the O(1) service the integral histogram exists to enable).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import binning as _binning
from .kernels import prescan as _prescan
from .kernels import tiled_scan as _tiled
from .kernels import transpose as _transpose
from .kernels import wavefront as _wavefront

# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def cw_b(image: jnp.ndarray, bins: int, tile: int = 32) -> jnp.ndarray:
    """Algorithm 2: naive cross-weave baseline.

    The GPU version launches b×h one-row scans, b 2-D transposes and b×w
    more one-row scans — bins are processed one at a time with small
    generic kernels.  A single HLO module cannot "launch" kernels, so the
    per-bin sequencing is expressed as ``lax.map`` over bins (one bin's
    full scan→transpose→scan→transpose chain per loop step), and the
    Rust ``simulator`` module adds the measured per-launch cost on top —
    the paper itself attributes CW-B's 30×+ deficit to launch overhead
    and under-utilization (§3.3), which is exactly what the model
    charges.  (An earlier trace-time-unrolled formulation produced an
    HLO that xla_extension 0.5.1 took ~8 minutes to compile; see
    EXPERIMENTS.md §Perf, L2 iteration 1.)
    """

    def per_bin(b):
        q = (image == b).astype(jnp.float32)
        hs = _prescan.inclusive_scan_rows(q)  # b×h row scans
        ht = _transpose.transpose2d(hs, tile)  # per-bin 2-D transpose
        vs = _prescan.inclusive_scan_rows(ht)  # b×w column scans
        return _transpose.transpose2d(vs, tile)

    return jax.lax.map(per_bin, jnp.arange(bins, dtype=image.dtype))


def cw_sts(image: jnp.ndarray, bins: int, tile: int = 32) -> jnp.ndarray:
    """Algorithm 3: single scan → 3-D transpose → single scan.

    The SDK prescan kernel is launched over one large 2-D grid covering
    all (b·h) rows at once, fixing CW-B's under-utilization while keeping
    the work-inefficient Blelloch scan and the transpose data movement.
    """
    h, w = image.shape
    q = _binning.binning(image, bins, tile)
    hs = _prescan.inclusive_scan_rows(q.reshape(bins * h, w)).reshape(bins, h, w)
    ht = _transpose.transpose3d(hs, tile)  # (b, w, h)
    vs = _prescan.inclusive_scan_rows(ht.reshape(bins * w, h)).reshape(bins, w, h)
    return _transpose.transpose3d(vs, tile)


def cw_tis(image: jnp.ndarray, bins: int, tile: int = 64) -> jnp.ndarray:
    """Algorithm 4: cross-weave tiled horizontal-vertical strip scans."""
    q = _binning.binning(image, bins, tile)
    return _tiled.tiled_vscan(_tiled.tiled_hscan(q, tile), tile)


def wf_tis(image: jnp.ndarray, bins: int, tile: int = 64) -> jnp.ndarray:
    """Algorithm 5: fused wavefront tiled scan (binning fused in-kernel)."""
    return _wavefront.wf_tis(image, bins, tile)


STRATEGIES = {
    "cw_b": cw_b,
    "cw_sts": cw_sts,
    "cw_tis": cw_tis,
    "wf_tis": wf_tis,
}

# ---------------------------------------------------------------------------
# Auxiliary graphs
# ---------------------------------------------------------------------------


def init_only(image: jnp.ndarray, bins: int, tile: int = 64) -> jnp.ndarray:
    """Binning/initialization alone — the "init" slice of Fig. 8."""
    return _binning.binning(image, bins, tile)


def region_query(ih: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """Eq. 2 as a batched gather graph: (b,h,w) IH + (n,4) rects → (n,b).

    Rectangles are inclusive (r0, c0, r1, c1).  The IH is zero-padded on
    the top/left so border guards become plain gathers; must stay in sync
    with kernels.ref.region_histogram_batch.
    """
    padded = jnp.pad(ih, ((0, 0), (1, 0), (1, 0)))
    r0, c0, r1, c1 = rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3]
    a = padded[:, r1 + 1, c1 + 1]
    b = padded[:, r0, c1 + 1]
    c = padded[:, r1 + 1, c0]
    d = padded[:, r0, c0]
    return (a - b - c + d).T


def wf_tis_with_query(image: jnp.ndarray, rects: jnp.ndarray, bins: int, tile: int = 64):
    """Fused serving graph: integral histogram + batched region queries.

    This is the shape the L3 batcher actually serves: one frame in, the
    IH *and* the histograms of a batch of query rectangles out.
    """
    ih = wf_tis(image, bins, tile)
    return ih, region_query(ih, rects)


def pad_image(image: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Pad an image so both dims are tile multiples (§3.4 padding rule).

    Padding uses bin value −1 so padded pixels fall in no bin and the
    integral histogram of the original extent is unchanged.
    """
    h, w = image.shape
    ph = (tile - h % tile) % tile
    pw = (tile - w % tile) % tile
    if ph == 0 and pw == 0:
        return image
    return jnp.pad(image, ((0, ph), (0, pw)), constant_values=-1)
