"""L2 profiling tool: static cost analysis of every lowered artifact.

``python -m compile.analyze`` prints, per artifact: XLA's own FLOP /
byte-traffic estimates (jax cost analysis of the compiled module), the
arithmetic-intensity ratio, and the Pallas-side VMEM footprint of one
grid step — the inputs behind DESIGN.md §6's TPU performance estimate
and the §Perf "no redundant recomputation" check (EXPERIMENTS.md).

Pure build-time tooling; never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from . import model
from .kernels import wavefront


def cost_of(fn, *specs) -> dict:
    """Compile and return XLA's cost analysis for a jax callable."""
    compiled = jax.jit(fn).lower(*specs).compile()
    analyses = compiled.cost_analysis()
    # jax returns one dict (new API) or a list of dicts (old API)
    ca = analyses[0] if isinstance(analyses, (list, tuple)) else analyses
    return dict(ca) if ca else {}


def analyze_strategy(name: str, h: int, w: int, bins: int, tile: int) -> dict:
    fn = model.STRATEGIES[name]
    spec = jax.ShapeDtypeStruct((h, w), jnp.int32)
    ca = cost_of(lambda img: (fn(img, bins, tile),), spec)
    flops = float(ca.get("flops", 0.0))
    bytes_total = float(ca.get("bytes accessed", 0.0))
    tensor_bytes = bins * h * w * 4
    out = {
        "strategy": name,
        "size": f"{h}x{w}",
        "bins": bins,
        "tile": tile,
        "flops": flops,
        "bytes_accessed": bytes_total,
        "intensity_flops_per_byte": flops / bytes_total if bytes_total else 0.0,
        "tensor_passes_equiv": bytes_total / tensor_bytes if tensor_bytes else 0.0,
    }
    if name == "wf_tis":
        out["vmem_per_grid_step_bytes"] = wavefront.vmem_bytes(tile, w)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--bins", type=int, default=32)
    ap.add_argument("--json", action="store_true", help="emit machine-readable JSON")
    args = ap.parse_args()

    rows = []
    for name, tile in [("cw_b", 32), ("cw_sts", 32), ("cw_tis", 64), ("wf_tis", 64)]:
        rows.append(analyze_strategy(name, args.size, args.size, args.bins, tile))

    if args.json:
        print(json.dumps(rows, indent=2))
        return
    print(f"artifact cost analysis @ {args.size}x{args.size}, {args.bins} bins")
    print(f"{'strategy':<8} {'GFLOP':>8} {'GB moved':>9} {'F/B':>6} {'tensor passes':>14}")
    for r in rows:
        print(
            f"{r['strategy']:<8} {r['flops'] / 1e9:>8.3f} {r['bytes_accessed'] / 1e9:>9.3f}"
            f" {r['intensity_flops_per_byte']:>6.2f} {r['tensor_passes_equiv']:>14.1f}"
        )
    wf = rows[-1]
    if "vmem_per_grid_step_bytes" in wf:
        print(
            f"\nWF-TiS VMEM per grid step: {wf['vmem_per_grid_step_bytes'] / 1024:.1f} KiB"
            f" (budget 16 MiB — {wf['vmem_per_grid_step_bytes'] / (16 << 20) * 100:.2f}%)"
        )
    ordered = sorted(rows, key=lambda r: r["bytes_accessed"])
    print(
        "traffic ordering: "
        + " < ".join(r["strategy"] for r in ordered)
        + "   (paper §3.5 predicts wf_tis < cw_tis < cw_sts ≤ cw_b)"
    )


if __name__ == "__main__":
    main()
