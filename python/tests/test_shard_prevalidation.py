"""Pre-validation of the rust/src/shard/ subsystem's two novel
algorithms, mirrored in NumPy (the dev container ships no Rust
toolchain; the Rust property tests in rust/tests/shard_property.rs
assert the same invariants in-tree).

1. Planner (mirror of ShardPlanner::plan): every (bin, row) of the
   tensor is covered by exactly one shard, shard bytes respect the
   per-shard budget slice, ids are dense in issue order.
2. Reassembly (mirror of Reassembler): a row strip's local integral
   plus the per-column carry of the strip above equals the full
   integral — bit-identically for count-valued float32 tensors — in
   any arrival order.

Run: python3 python/tests/test_shard_prevalidation.py  (or pytest)
"""

import numpy as np


def ceil_div(a, b):
    return -(-a // b)


def plan(bins, h, w, budget, workers, max_group=16, min_shards=0):
    """Mirror of ShardPlanner::plan (keep in sync)."""
    workers = max(workers, 1)
    slack = 4 * workers + 4
    per = max(budget // slack, w * 4)
    plane = h * w * 4
    by_budget = min(max(per // plane, 1), bins)
    group = min(max(max_group, 1), by_budget)
    strip_rows = h
    if plane > per:
        group = 1
        strip_rows = min(max(per // (w * 4), 1), h)
    ms = workers if min_shards == 0 else min_shards
    n_groups = ceil_div(bins, group)
    if n_groups * ceil_div(h, strip_rows) < ms:
        want = min(ceil_div(ms, n_groups), h)
        strip_rows = max(min(strip_rows, ceil_div(h, want)), 1)
    shards = []
    b0 = 0
    while b0 < bins:
        nb = min(group, bins - b0)
        r0 = 0
        while r0 < h:
            nr = min(strip_rows, h - r0)
            shards.append((len(shards), b0, nb, r0, nr))
            r0 += nr
        b0 += nb
    return shards, per


def integral(img, bins):
    """Algorithm 1 in float32: bins x h x w double cumsum of Q."""
    onehot = (img[None, :, :] == np.arange(bins)[:, None, None]).astype(np.float32)
    return np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2, dtype=np.float32)


def local_partial(img, b0, nb, r0, nr):
    """The executor's shard job: slice rows, shift bins, local integral."""
    sub = img[r0 : r0 + nr, :].astype(np.int64) - b0
    sub[(sub < 0) | (sub >= nb)] = -1
    return integral(sub, nb)


def reassemble(shards, partials, bins, h, w, order):
    """Mirror of Reassembler: commit strips in row order per bin group,
    adding the carry row; park early arrivals."""
    out = np.zeros((bins, h, w), dtype=np.float32)
    next_row = {}
    carry = {}
    parked = {}

    def commit(sid):
        _, b0, nb, r0, nr = shards[sid]
        local = partials[sid]
        c = carry.get(b0, np.zeros((nb, w), dtype=np.float32))
        corrected = local + c[:, None, :]
        out[b0 : b0 + nb, r0 : r0 + nr, :] = corrected
        carry[b0] = corrected[:, -1, :].copy()
        next_row[b0] = r0 + nr

    for sid in order:
        _, b0, nb, r0, nr = shards[sid]
        if r0 != next_row.get(b0, 0):
            parked[(b0, r0)] = sid
            continue
        commit(sid)
        while (b0, next_row[b0]) in parked:
            commit(parked.pop((b0, next_row[b0])))
    assert not parked, "every shard must commit"
    return out


def check_cover(shards, bins, h, w, per):
    cover = np.zeros((bins, h), dtype=np.int32)
    for i, (sid, b0, nb, r0, nr) in enumerate(shards):
        assert sid == i, "dense issue-order ids"
        assert nb >= 1 and nr >= 1 and b0 + nb <= bins and r0 + nr <= h
        assert nb * nr * w * 4 <= per, "shard must respect the budget slice"
        cover[b0 : b0 + nb, r0 : r0 + nr] += 1
    assert (cover == 1).all(), "every (bin, row) exactly once"


def test_planner_cover_property():
    rng = np.random.default_rng(1)
    cases = [
        (1, 1, 1, 1 << 20, 1),
        (5, 1, 97, 1 << 10, 3),
        (5, 97, 1, 1 << 10, 3),
        (9, 7, 3, 256, 3),
        (8, 33, 47, 8 << 10, 3),
        (128, 96, 80, 256 << 10, 4),
        (128, 8192, 8192, 256 << 20, 4),
        (32, 192, 160, 64 << 20, 4),
    ]
    for _ in range(40):
        cases.append(
            (int(rng.integers(1, 40)), int(rng.integers(1, 120)), int(rng.integers(1, 120)),
             int(rng.integers(64, 1 << 22)), int(rng.integers(1, 6)))
        )
    for bins, h, w, budget, workers in cases:
        shards, per = plan(bins, h, w, budget, workers)
        check_cover(shards, bins, h, w, per)
    print(f"planner cover property: {len(cases)} cases OK")


def test_strip_carry_reassembly_bit_identity():
    rng = np.random.default_rng(7)
    cases = [
        (1, 1, 1, 1 << 20, 1),
        (5, 1, 97, 1 << 10, 3),
        (5, 97, 1, 1 << 10, 3),
        (9, 7, 3, 256, 3),
        (8, 33, 47, 8 << 10, 3),
        (128, 96, 80, 256 << 10, 4),
        (6, 44, 36, 12 << 10, 2),
    ]
    for bins, h, w, budget, workers in cases:
        img = rng.integers(0, bins, size=(h, w))
        expected = integral(img, bins)
        shards, _ = plan(bins, h, w, budget, workers)
        partials = {s[0]: local_partial(img, s[1], s[2], s[3], s[4]) for s in shards}
        for order in (
            list(range(len(shards))),              # in order
            list(range(len(shards)))[::-1],        # fully reversed
            list(rng.permutation(len(shards))),    # shuffled
        ):
            got = reassemble(shards, partials, bins, h, w, order)
            assert np.array_equal(got, expected), (
                f"strip-carry composition deviates at {bins}x{h}x{w}, "
                f"{len(shards)} shards"
            )
    print(f"strip-carry reassembly bit-identity: {len(cases)} cases x 3 orders OK")


def test_eq2_corner_query_against_spilled_layout():
    """Eq. 2 on the Fig. 2 flat file layout: four corner reads per bin
    equal the dense region histogram (mirror of TensorStore::query)."""
    rng = np.random.default_rng(3)
    bins, h, w = 12, 17, 29
    img = rng.integers(0, bins, size=(h, w))
    ih = integral(img, bins)
    flat = ih.astype("<f4").tobytes()  # the store's on-disk layout

    def corner(b, r, c):
        off = ((b * h + r) * w + c) * 4
        return np.frombuffer(flat[off : off + 4], dtype="<f4")[0]

    for _ in range(200):
        r0, r1 = sorted(rng.integers(0, h, 2))
        c0, c1 = sorted(rng.integers(0, w, 2))
        for b in range(bins):
            v = corner(b, r1, c1)
            if r0 > 0:
                v -= corner(b, r0 - 1, c1)
            if c0 > 0:
                v -= corner(b, r1, c0 - 1)
            if r0 > 0 and c0 > 0:
                v += corner(b, r0 - 1, c0 - 1)
            dense = (img[r0 : r1 + 1, c0 : c1 + 1] == b).sum()
            assert v == np.float32(dense), (b, r0, c0, r1, c1)
    print("Eq. 2 corner queries on the flat layout: 200 rects OK")


if __name__ == "__main__":
    test_planner_cover_property()
    test_strip_carry_reassembly_bit_identity()
    test_eq2_corner_query_against_spilled_layout()
    print("shard subsystem pre-validation: ALL OK")
