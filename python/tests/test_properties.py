"""Hypothesis property sweeps over the Pallas kernels.

Randomized shapes/bins/tiles/contents against the pure-jnp oracle —
the L1 analogue of the Rust property suite.  Sizes are kept small so the
sweep stays fast; the fixed-size artifact geometries are covered by
test_kernel.py and the Rust integration tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import prescan, ref, tiled_scan, transpose, wavefront

SETTINGS = dict(max_examples=25, deadline=None)

# tile must divide both dims: draw multipliers instead of raw sizes
tiles = st.sampled_from([8, 16, 32])
mults = st.integers(min_value=1, max_value=3)
bins_s = st.integers(min_value=1, max_value=16)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def image_for(seed, h, w, bins):
    return jax.random.randint(jax.random.PRNGKey(seed), (h, w), 0, bins, dtype=jnp.int32)


class TestWavefrontProperties:
    @settings(**SETTINGS)
    @given(tile=tiles, mh=mults, mw=mults, bins=bins_s, seed=seeds)
    def test_matches_oracle(self, tile, mh, mw, bins, seed):
        h, w = tile * mh, tile * mw
        img = image_for(seed, h, w, bins)
        out = wavefront.wf_tis(img, bins, tile)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.integral_histogram(img, bins)), atol=1e-4
        )

    @settings(**SETTINGS)
    @given(tile=tiles, m=mults, bins=bins_s, seed=seeds)
    def test_corner_is_total_mass(self, tile, m, bins, seed):
        h = w = tile * m
        img = image_for(seed, h, w, bins)
        out = wavefront.wf_tis(img, bins, tile)
        assert float(out[:, -1, -1].sum()) == h * w

    @settings(**SETTINGS)
    @given(tile=tiles, m=mults, bins=bins_s, seed=seeds)
    def test_monotone_along_axes(self, tile, m, bins, seed):
        h = w = tile * m
        img = image_for(seed, h, w, bins)
        out = np.asarray(wavefront.wf_tis(img, bins, tile))
        assert (np.diff(out, axis=1) >= -1e-5).all()
        assert (np.diff(out, axis=2) >= -1e-5).all()


class TestTiledScanProperties:
    @settings(**SETTINGS)
    @given(tile=tiles, mh=mults, mw=mults, b=st.integers(1, 8), seed=seeds)
    def test_hscan_then_vscan_is_integral(self, tile, mh, mw, b, seed):
        h, w = tile * mh, tile * mw
        x = jax.random.uniform(jax.random.PRNGKey(seed), (b, h, w))
        out = tiled_scan.tiled_vscan(tiled_scan.tiled_hscan(x, tile), tile)
        expected = jnp.cumsum(jnp.cumsum(x, axis=1), axis=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-4, atol=1e-3)

    @settings(**SETTINGS)
    @given(tile=tiles, mh=mults, mw=mults, seed=seeds)
    def test_scan_order_commutes(self, tile, mh, mw, seed):
        # cross-weave property: h-then-v equals v-then-h
        h, w = tile * mh, tile * mw
        x = jax.random.uniform(jax.random.PRNGKey(seed), (2, h, w))
        a = tiled_scan.tiled_vscan(tiled_scan.tiled_hscan(x, tile), tile)
        b = tiled_scan.tiled_hscan(tiled_scan.tiled_vscan(x, tile), tile)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-3)


class TestPrescanProperties:
    @settings(**SETTINGS)
    @given(rows=st.integers(1, 4), n=st.sampled_from([32, 64, 128, 256]), seed=seeds)
    def test_blelloch_is_exclusive_scan(self, rows, n, seed):
        x = jax.random.uniform(jax.random.PRNGKey(seed), (rows * 8, n))
        out = prescan.prescan_rows(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(jnp.cumsum(x, axis=1) - x), rtol=1e-5, atol=1e-5
        )

    @settings(**SETTINGS)
    @given(n=st.integers(1, 300), seed=seeds)
    def test_inclusive_any_width(self, n, seed):
        x = jax.random.uniform(jax.random.PRNGKey(seed), (8, n))
        out = prescan.inclusive_scan_rows(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.cumsum(x, axis=1)), rtol=1e-5, atol=1e-5)


class TestTransposeProperties:
    @settings(**SETTINGS)
    @given(tile=st.sampled_from([8, 16, 32]), mh=mults, mw=mults, seed=seeds)
    def test_transpose_involution(self, tile, mh, mw, seed):
        h, w = tile * mh, tile * mw
        x = jax.random.uniform(jax.random.PRNGKey(seed), (h, w))
        back = transpose.transpose2d(transpose.transpose2d(x, tile), tile)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


class TestStrategyEquivalenceProperties:
    @settings(max_examples=8, deadline=None)
    @given(bins=st.integers(1, 8), seed=seeds)
    def test_all_strategies_agree(self, bins, seed):
        img = image_for(seed, 64, 64, bins)
        outs = {n: np.asarray(fn(img, bins, 32)) for n, fn in model.STRATEGIES.items()}
        expected = np.asarray(ref.integral_histogram(img, bins))
        for n, o in outs.items():
            np.testing.assert_allclose(o, expected, atol=1e-3, err_msg=n)

    @settings(max_examples=10, deadline=None)
    @given(
        bins=st.integers(1, 8),
        seed=seeds,
        r0=st.integers(0, 63),
        c0=st.integers(0, 63),
        dr=st.integers(0, 63),
        dc=st.integers(0, 63),
    )
    def test_region_query_counts_pixels(self, bins, seed, r0, c0, dr, dc):
        img = image_for(seed, 64, 64, bins)
        ih = ref.integral_histogram(img, bins)
        r1, c1 = min(r0 + dr, 63), min(c0 + dc, 63)
        rects = jnp.array([[r0, c0, r1, c1]], jnp.int32)
        hist = np.asarray(model.region_query(ih, rects))[0]
        window = np.asarray(img)[r0 : r1 + 1, c0 : c1 + 1]
        expected = np.bincount(window.ravel(), minlength=bins).astype(np.float32)
        np.testing.assert_allclose(hist, expected, atol=1e-3)
