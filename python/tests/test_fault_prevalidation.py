"""Pre-validation of the rust/src/fault/ supervision protocol, mirrored
in Python (the dev container ships no Rust toolchain; the Rust chaos
tests in rust/tests/fault_property.rs assert the same invariants
in-tree, built with `--features fault-injection`).

1. Schedule (mirror of fault::fault_roll / FaultInjector::decide):
   splitmix64-hashed decisions are deterministic, in [0, 1), partition
   the probability mass, respect the per-site injection cap, and are
   interleaving-independent (racing threads inject the same multiset).
2. Corruption (mirror of fault::corrupt_bytes): deterministic one-byte
   flip that always changes the buffer.
3. Checksums (mirror of shard::store FNV-1a rows): a transient read
   corruption is healed by one reread; a persistent write corruption is
   detected and surfaces a typed checksum-mismatch error, never data.
4. Supervision (mirror of shard::executor retry loop): under a seeded
   schedule of panics/errors, every frame either reassembles
   bit-identically or fails typed; attempts are bounded; the injected
   and observed failure counters reconcile exactly; a watchdog proves
   no hangs.
5. Worker replacement (mirror of WorkerPool::replace_dead): dead
   workers are detected and respawned, the `replaced` counter matches,
   and the pool keeps serving.

Run: python3 python/tests/test_fault_prevalidation.py  (or pytest)
"""

import queue
import threading

import numpy as np

MASK64 = (1 << 64) - 1

SITES = ("shard_compute", "spill_write", "spill_read", "compile", "worker_abort")


def splitmix64(z):
    """Mirror of fault::splitmix64 (keep in sync)."""
    z = (z + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


def fault_roll(seed, site_index, n):
    """Mirror of fault::fault_roll (keep in sync)."""
    h = splitmix64(seed ^ splitmix64(site_index ^ ((n * 0xA0761D6478BD642F) & MASK64)))
    return (h >> 11) * (1.0 / float(1 << 53))


def corrupt_bytes(buf, salt):
    """Mirror of fault::corrupt_bytes: flip one byte, mask | 1 so the
    buffer always changes."""
    if not buf:
        return buf
    h = splitmix64(salt)
    pos = h % len(buf)
    mask = ((h >> 32) & 0xFF) | 1
    out = bytearray(buf)
    out[pos] ^= mask
    return bytes(out)


def fnv1a32(data):
    """Mirror of shard::store::fnv1a32 (keep in sync)."""
    h = 0x811C9DC5
    for b in data:
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h


class Injector:
    """Mirror of FaultInjector::decide for the ShardCompute site:
    thread-safe occurrence counter, probability partition, per-site
    injection cap."""

    def __init__(self, seed, p_panic, p_error, p_delay=0.0, cap=0):
        assert p_panic + p_error + p_delay <= 1.0
        self.seed, self.pp, self.pe, self.pd, self.cap = seed, p_panic, p_error, p_delay, cap
        self.occ = 0
        self.injected = 0
        self.panics = self.errors = self.delays = 0
        self._mx = threading.Lock()

    def decide(self):
        with self._mx:
            n = self.occ
            self.occ += 1
            if self.cap and self.injected >= self.cap:
                return None
            u = fault_roll(self.seed, 0, n)
            if u < self.pp:
                action = "panic"
                self.panics += 1
            elif u < self.pp + self.pe:
                action = "error"
                self.errors += 1
            elif u < self.pp + self.pe + self.pd:
                action = "delay"
                self.delays += 1
            else:
                return None
            self.injected += 1
            return action


def test_roll_determinism_and_partition():
    a = [fault_roll(42, 0, n) for n in range(512)]
    b = [fault_roll(42, 0, n) for n in range(512)]
    assert a == b, "schedule must be pure in (seed, site, n)"
    assert all(0.0 <= u < 1.0 for u in a)
    assert a != [fault_roll(42, 2, n) for n in range(512)], "sites decorrelate"
    assert a != [fault_roll(43, 0, n) for n in range(512)], "seeds decorrelate"
    # Empirical mass ≈ uniform: the decide() partition sees each band
    # at roughly its probability.
    lo = sum(1 for u in a if u < 0.05) / len(a)
    assert 0.0 <= lo <= 0.15, f"p<0.05 band frequency {lo} wildly non-uniform"
    print("fault_roll: deterministic, uniform, decorrelated across sites/seeds")


def test_injector_cap_and_interleaving_independence():
    serial = Injector(77, 0.1, 0.2, 0.05)
    seq = [serial.decide() for _ in range(400)]

    racy = Injector(77, 0.1, 0.2, 0.05)
    threads = [
        threading.Thread(target=lambda: [racy.decide() for _ in range(100)]) for _ in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert racy.occ == serial.occ == 400
    assert (racy.panics, racy.errors, racy.delays) == (
        serial.panics,
        serial.errors,
        serial.delays,
    ), "multiset of injected faults must not depend on interleaving"

    capped = Injector(77, 0.5, 0.5, 0.0, cap=7)
    for _ in range(200):
        capped.decide()
    assert capped.injected == 7, "cap bounds the schedule"
    assert seq.count("panic") == serial.panics
    print("injector: cap honoured, interleaving-independent multiset")


def test_corrupt_bytes_always_changes():
    for salt in range(64):
        buf = bytes(range(32))
        out = corrupt_bytes(buf, salt)
        assert out != buf, "corruption must be observable"
        assert sum(x != y for x, y in zip(out, buf)) == 1, "exactly one byte flips"
        assert corrupt_bytes(buf, salt) == out, "deterministic in salt"
    assert corrupt_bytes(b"", 1) == b"", "empty buffer is a no-op"
    print("corrupt_bytes: deterministic single-byte flip, never silent")


def test_checksum_reread_protocol():
    """Mirror of TensorStore read_rows: verify → reread once → typed
    error, distinguishing transient (read-side) from persistent
    (write-side) corruption."""
    rng = np.random.default_rng(5)
    rows = [rng.random(40).astype("<f4").tobytes() for _ in range(16)]
    sums = [fnv1a32(r) for r in rows]  # write-side checksums
    disk = list(rows)

    def read_row(i, transient_corrupt=False):
        """Returns (data, rereads, failed)."""
        data = disk[i]
        if transient_corrupt:
            data = corrupt_bytes(data, salt=i)  # bad bytes AFTER the read
        if fnv1a32(data) == sums[i]:
            return data, 0, False
        data = disk[i]  # one reread, straight from "disk"
        if fnv1a32(data) == sums[i]:
            return data, 1, False
        return None, 1, True

    # Clean reads verify with no rereads.
    for i in range(16):
        d, rr, failed = read_row(i)
        assert d == rows[i] and rr == 0 and not failed

    # Transient read corruption: healed by the reread, data intact.
    d, rr, failed = read_row(3, transient_corrupt=True)
    assert d == rows[3] and rr == 1 and not failed, "reread must heal transient corruption"

    # Persistent write corruption: bad bytes reached disk; the reread
    # still mismatches and the row FAILS — corrupt data is never served.
    disk[7] = corrupt_bytes(disk[7], salt=99)
    d, rr, failed = read_row(7)
    assert failed and rr == 1 and d is None, "persistent corruption must fail typed"
    print("checksums: transient corruption healed by reread, persistent detected")


def supervised_run(seed, frames, shards_per_frame, max_attempts, workers, p_panic, p_error):
    """Mirror of the ShardExecutor retry loop: workers pull shard jobs,
    each compute attempt consults the schedule; panics are caught
    (worker survives), failed attempts retry up to max_attempts, then
    the shard — and its frame — fails typed.  Returns reconciliation
    counters."""
    inj = Injector(seed, p_panic, p_error)
    jobs = queue.Queue()
    results = {f: queue.Queue() for f in range(frames)}
    stats = {"attempt_failures": 0, "attempt_panics": 0, "recovered": 0, "shard_failed": 0}
    mx = threading.Lock()
    alive = threading.Semaphore(0)

    def worker():
        while True:
            job = jobs.get()
            if job is None:
                alive.release()  # still alive at shutdown: count me
                return
            frame, sid = job
            failed_attempts = 0
            outcome = None
            for _ in range(max_attempts):
                action = inj.decide()
                try:
                    if action == "panic":
                        raise RuntimeError("injected panic")
                    if action == "error":
                        outcome = ("error", sid)
                        failed_attempts += 1
                        continue
                    outcome = ("ok", sid, sid * 1000 + frame)  # deterministic payload
                    break
                except RuntimeError:
                    # catch_unwind: the worker SURVIVES its panic.
                    with mx:
                        stats["attempt_panics"] += 1
                    failed_attempts += 1
                    outcome = ("panicked", sid)
            with mx:
                stats["attempt_failures"] += failed_attempts
                if outcome[0] == "ok":
                    if failed_attempts:
                        stats["recovered"] += 1
                else:
                    stats["shard_failed"] += 1
            results[frame].put(outcome)

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for f in range(frames):
        for sid in range(shards_per_frame):
            jobs.put((f, sid))

    ok_frames = failed_frames = 0
    for f in range(frames):
        got, typed_failure = {}, None
        for _ in range(shards_per_frame):
            # Bounded wait IS the deadline: queue.get(timeout) raising
            # would mean a lost shard → hang in the Rust version.
            msg = results[f].get(timeout=30)
            if msg[0] == "ok":
                got[msg[1]] = msg[2]
            else:
                typed_failure = msg
        if typed_failure is None:
            assert got == {s: s * 1000 + f for s in range(shards_per_frame)}, "bit-identical"
            ok_frames += 1
        else:
            assert typed_failure[0] in ("error", "panicked"), "failure must be typed"
            failed_frames += 1

    for _ in threads:
        jobs.put(None)
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "watchdog: worker hung"
    survivors = sum(1 for _ in range(workers) if alive.acquire(blocking=False))
    return inj, stats, ok_frames, failed_frames, survivors


def test_supervised_retry_protocol():
    for seed in (1, 7, 42):
        inj, st, ok, failed, survivors = supervised_run(
            seed,
            frames=40,
            shards_per_frame=6,
            max_attempts=4,
            workers=3,
            p_panic=0.05,
            p_error=0.10,
        )
        # Reconciliation: every injected fault was observed as exactly
        # one failed attempt, and nothing else was.
        assert st["attempt_failures"] == inj.panics + inj.errors, (seed, st)
        assert st["attempt_panics"] == inj.panics, (seed, st)
        assert ok + failed == 40
        assert ok > 0, "some frames must survive chaos"
        assert survivors == 3, "workers must survive caught panics"
        # With attempts=4 and p(fault)=0.15 per attempt, losing a shard
        # needs 4 consecutive faults — rare but legal; if it happened it
        # was typed, which the frame loop already asserted.
    print("supervision: frames bit-identical or typed, counters reconcile, no hangs")


def test_worker_replacement_epoch():
    """Mirror of WorkerPool::replace_dead: a poisoned job kills its
    worker; the pool detects the dead slot, respawns it, and keeps
    serving.  `replaced` is counter-asserted."""
    jobs, results = queue.Queue(), queue.Queue()

    def worker_loop():
        while True:
            j = jobs.get()
            if j is None:
                return
            if j == "die":
                raise SystemExit  # worker thread dies mid-fleet
            results.put(j * 2)

    slots = [threading.Thread(target=worker_loop) for _ in range(3)]
    for t in slots:
        t.start()
    for j in (1, "die", 2, "die", 3):
        jobs.put(j)
    deadline = [results.get(timeout=10) for _ in range(3)]
    assert sorted(deadline) == [2, 4, 6]

    replaced = 0
    import time

    time.sleep(0.1)  # let the dead workers actually exit
    for i, t in enumerate(slots):
        if not t.is_alive():  # epoch scan: dead slot detected
            slots[i] = threading.Thread(target=worker_loop)
            slots[i].start()
            replaced += 1
    assert replaced == 2, f"both killed workers must be detected, got {replaced}"
    for j in (10, 20, 30):
        jobs.put(j)
    assert sorted(results.get(timeout=10) for _ in range(3)) == [20, 40, 60]
    for _ in slots:
        jobs.put(None)
    for t in slots:
        t.join(timeout=10)
        assert not t.is_alive()
    print("worker replacement: dead slots detected, respawned, pool keeps serving")


if __name__ == "__main__":
    test_roll_determinism_and_partition()
    test_injector_cap_and_interleaving_independence()
    test_corrupt_bytes_always_changes()
    test_checksum_reread_protocol()
    test_supervised_retry_protocol()
    test_worker_replacement_epoch()
    print("fault supervision pre-validation: ALL OK")
