"""Tests for the build-path tooling: the interpreter performance patch
(must be semantics-preserving) and the cost-analysis tool."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import analyze, interpret_patch, model
from compile.kernels import ref, tiled_scan, wavefront


class TestInterpretPatch:
    def test_patched_matches_stock_interpreter(self):
        """The write-back-elision patch must not change any result."""
        img = jax.random.randint(jax.random.PRNGKey(0), (64, 96), 0, 8, dtype=jnp.int32)
        x = jax.random.uniform(jax.random.PRNGKey(1), (4, 64, 64))
        interpret_patch.apply()
        patched_wf = np.asarray(wavefront.wf_tis(img, 8, 32))
        patched_h = np.asarray(tiled_scan.tiled_hscan(x, 32))
        try:
            interpret_patch.remove()
            stock_wf = np.asarray(wavefront.wf_tis(img, 8, 32))
            stock_h = np.asarray(tiled_scan.tiled_hscan(x, 32))
        finally:
            interpret_patch.apply()
        np.testing.assert_array_equal(patched_wf, stock_wf)
        np.testing.assert_array_equal(patched_h, stock_h)

    def test_apply_is_idempotent(self):
        interpret_patch.apply()
        interpret_patch.apply()
        img = jax.random.randint(jax.random.PRNGKey(2), (32, 32), 0, 4, dtype=jnp.int32)
        np.testing.assert_allclose(
            np.asarray(wavefront.wf_tis(img, 4, 16)),
            np.asarray(ref.integral_histogram(img, 4)),
            atol=1e-4,
        )

    def test_written_mask_detects_output_blocks(self):
        # indirect check: strategies still match the oracle end-to-end
        img = jax.random.randint(jax.random.PRNGKey(3), (64, 64), 0, 4, dtype=jnp.int32)
        for name, fn in model.STRATEGIES.items():
            np.testing.assert_allclose(
                np.asarray(fn(img, 4, 32)),
                np.asarray(ref.integral_histogram(img, 4)),
                atol=1e-3,
                err_msg=name,
            )


class TestAnalyze:
    def test_strategy_analysis_fields(self):
        r = analyze.analyze_strategy("wf_tis", 64, 64, 8, 32)
        assert r["strategy"] == "wf_tis"
        assert r["bytes_accessed"] > 0
        assert r["tensor_passes_equiv"] > 0
        assert r["vmem_per_grid_step_bytes"] == 32 * 32 * 8 + 32 * 4 + 64 * 4

    def test_wavefront_moves_less_than_sts(self):
        wf = analyze.analyze_strategy("wf_tis", 64, 64, 8, 32)
        sts = analyze.analyze_strategy("cw_sts", 64, 64, 8, 32)
        assert wf["bytes_accessed"] < sts["bytes_accessed"], (
            "the §3.5 traffic argument must show up in XLA's own accounting"
        )
