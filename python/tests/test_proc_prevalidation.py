"""Pre-validation of the rust/src/proc/ multi-process execution plane,
mirrored in Python (the dev container ships no Rust toolchain; the Rust
side asserts the same invariants in-tree: protocol unit tests in
rust/src/proc/protocol.rs, process-boundary property tests in
rust/tests/proc_property.rs).

1. Framing (mirror of proc::protocol::ProcMsg, wire v3): byte-exact
   encode / decode of every message type over the
   `[magic u16 LE][version u16 LE][type u8][len u32 LE][payload]`
   wire format.  v2 appends the shm data-plane tail to `AssignShard`
   (`plane u8, slot u64, slot_off u64, ring_bytes u64, ring_path str`)
   and a `slot u64` to `ShardDone`.  v3 appends the remote-worker
   tail: `deadline_us u64` (remaining budget at dispatch, 0 = none)
   and `strip_checksum u32` to `AssignShard`, a `deadline` bool byte
   to `ShardFailed`, plus two new frame types — `Chunk` (bounded
   stream-plane payload slice, dir 0 = strip parent→child, 1 = partial
   child→parent) and `Hello` (socket handshake: version + capability
   bits).  v1/v2 frames still decode, as file/shm-plane payloads with
   no deadline (minor version bumps); Chunk/Hello under a pre-v3
   header are unknown types.  Truncation at EVERY byte prefix, foreign
   magic, version skew, unknown types, oversized lengths, trailing
   payload bytes, degenerate shard geometry, hostile slot geometry
   (region past the ring, ringless shm assign, unknown plane byte) and
   hostile chunk geometry (bad dir byte, data past the declared total,
   offset overflow, oversized data) all land in a typed error — never
   a crash, never a partially-decoded message.
2. Checksum (mirror of proc::protocol::checksum_f32 / checksum_bytes):
   FNV-1a — deterministic, bit-sensitive, empty input is the basis.
3. Stream plane (mirror of supervisor.rs stream_rx / worker.rs
   PendingStream): chunked payloads must arrive dense and in order; a
   gap, replay or overrun drops the buffer and fails typed — never a
   torn reassembly.  Deadlines cross the clock domain as remaining
   budget anchored at arrival (worker.rs deadline_expired), so skew
   between parent and worker clocks can never expire a fresh shard.
4. Supervision (mirror of proc::supervisor::ProcSupervisor): a
   deterministic state machine driving dispatch / child death /
   heartbeat timeout proves the requeue ladder — a dead child's
   in-flight shards are requeued with attempts+1 and complete on the
   replacement; a shard that exhausts max_attempts fails its frame
   typed EXACTLY once; the frame's outstanding count drains to zero and
   its image spill file is cleaned up exactly once; an expired deadline
   drops shards before any dispatch.  The shm-plane additions: ring
   slots acquired at dispatch are released on completion and RECLAIMED
   when a child is reaped mid-flight (counter-asserted), and the
   heartbeat watchdog defers enforcement until a child's first message
   (the boot false-kill fix) with a boot-grace backstop for children
   that never speak at all.  The remote additions: a dropped socket
   link reconnects under a bounded backoff ladder (in-flight shards
   burn one attempt each, exactly like a local death); reconnect
   exhaustion leaves the slot dead and frames fail typed, never
   silent; a worker-side deadline skip (`ShardFailed{deadline:true}`)
   is charged to the deadline counter, not the retry ladder.

Run: python3 python/tests/test_proc_prevalidation.py  (or pytest)
"""

import struct
from collections import deque

MAGIC = 0x4948  # "IH"
VERSION = 3
VERSION_MIN = 1  # v1 = file-plane payloads, still decoded
MAX_PAYLOAD = 1 << 20
HEADER_LEN = 9
PLANE_FILE, PLANE_SHM, PLANE_STREAM = 0, 1, 2
NO_SLOT = (1 << 64) - 1
CHUNK_DATA_MAX = 256 * 1024
U64 = 1 << 64
CAP_STREAM, CAP_DEADLINE = 1, 2
CAPS_ALL = CAP_STREAM | CAP_DEADLINE

TY_ASSIGN, TY_DONE, TY_FAILED, TY_HEARTBEAT, TY_CALIBRATION, TY_SHUTDOWN = 1, 2, 3, 4, 5, 6
TY_CHUNK, TY_HELLO = 7, 8  # v3+


class ProtocolError(Exception):
    """kind in: truncated, bad_magic, version_mismatch, oversized,
    unknown_type, malformed — the ProtocolError variant surface."""

    def __init__(self, kind, detail=""):
        super().__init__(f"{kind}: {detail}")
        self.kind = kind


def fnv1a32(data):
    """Mirror of proc::protocol::checksum_f32's inner loop (keep in
    sync with shard::store::fnv1a32 — same constants)."""
    h = 0x811C9DC5
    for b in data:
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h


def checksum_f32(values):
    """Mirror of proc::protocol::checksum_f32: FNV-1a over f32 LE bytes."""
    return fnv1a32(struct.pack(f"<{len(values)}f", *values))


def _put_string(out, s):
    b = s.encode("utf-8")
    out += struct.pack("<I", len(b)) + b


def encode(msg, version=VERSION):
    """Mirror of ProcMsg::encode — msg is (type_name, fields dict).
    `version=1` emits legacy file-plane frames for the compat tests."""
    ty_name, f = msg
    p = bytearray()
    if ty_name == "assign":
        ty = TY_ASSIGN
        for k in ("frame_id", "shard_id", "bin0", "nbins", "row0", "nrows", "img_h", "img_w"):
            p += struct.pack("<Q", f[k])
        _put_string(p, f["img_path"])
        _put_string(p, f["out_path"])
        if version >= 2:
            # shm data-plane tail (protocol.rs v2): plane, slot,
            # slot_off, ring_bytes, ring_path.
            p += bytes([f["plane"]])
            p += struct.pack("<QQQ", f["slot"], f["slot_off"], f["ring_bytes"])
            _put_string(p, f["ring_path"])
        if version >= 3:
            # remote tail (protocol.rs v3): deadline budget + stream
            # strip checksum.
            p += struct.pack("<QI", f["deadline_us"], f["strip_checksum"])
    elif ty_name == "done":
        ty = TY_DONE
        p += struct.pack("<QQQI", f["frame_id"], f["shard_id"], f["kernel_time_us"], f["checksum"])
        if version >= 2:
            p += struct.pack("<Q", f["slot"])
    elif ty_name == "failed":
        ty = TY_FAILED
        p += struct.pack("<QQ", f["frame_id"], f["shard_id"])
        p += bytes([1 if f["panicked"] else 0])
        _put_string(p, f["reason"])
        if version >= 3:
            # v3 tail: deadline-skip marker.
            p += bytes([1 if f["deadline"] else 0])
    elif ty_name == "chunk":
        ty = TY_CHUNK
        p += struct.pack("<QQ", f["frame_id"], f["shard_id"])
        p += bytes([f["dir"]])
        p += struct.pack("<QQI", f["offset"], f["total"], len(f["data"]))
        p += f["data"]
    elif ty_name == "hello":
        ty = TY_HELLO
        p += struct.pack("<HI", f["version"], f["caps"])
        _put_string(p, f["tag"])
    elif ty_name == "heartbeat":
        ty = TY_HEARTBEAT
        p += struct.pack("<Q", f["seq"])
    elif ty_name == "calibration":
        ty = TY_CALIBRATION
        p += struct.pack("<d", f["memcpy_bps"])
        for t in f["tile_throughput"] + f["tile_throughput_tuned"]:
            p += struct.pack("<d", t)
        p += struct.pack("<ddd", f["dispatch_overhead_s"], f["spill_read_latency_s"], f["spill_read_bps"])
        p += struct.pack("<Q", f["samples"])
    elif ty_name == "shutdown":
        ty = TY_SHUTDOWN
    else:
        raise AssertionError(ty_name)
    assert len(p) <= MAX_PAYLOAD
    return struct.pack("<HHBI", MAGIC, version, ty, len(p)) + bytes(p)


class _Cursor:
    def __init__(self, buf):
        self.buf, self.pos = buf, 0

    def take(self, n):
        if self.pos + n > len(self.buf):
            raise ProtocolError("truncated")
        s = self.buf[self.pos : self.pos + n]
        self.pos += n
        return s

    def u32(self):
        return struct.unpack("<I", self.take(4))[0]

    def u64(self):
        return struct.unpack("<Q", self.take(8))[0]

    def f64(self):
        return struct.unpack("<d", self.take(8))[0]

    def string(self):
        n = self.u32()
        if n > MAX_PAYLOAD:
            raise ProtocolError("malformed", f"string length {n}")
        try:
            return self.take(n).decode("utf-8")
        except UnicodeDecodeError:
            raise ProtocolError("malformed", "non-UTF-8 string")

    def done(self):
        if self.pos != len(self.buf):
            raise ProtocolError("malformed", f"{len(self.buf) - self.pos} trailing payload bytes")


def decode(buf):
    """Mirror of ProcMsg::decode: returns (msg, used) or raises a typed
    ProtocolError.  Total over arbitrary bytes."""
    if len(buf) < HEADER_LEN:
        raise ProtocolError("truncated")
    magic, version, ty, plen = struct.unpack("<HHBI", buf[:HEADER_LEN])
    if magic != MAGIC:
        raise ProtocolError("bad_magic", hex(magic))
    if not (VERSION_MIN <= version <= VERSION):
        raise ProtocolError("version_mismatch", str(version))
    if plen > MAX_PAYLOAD:
        raise ProtocolError("oversized", str(plen))
    if len(buf) < HEADER_LEN + plen:
        raise ProtocolError("truncated")
    c = _Cursor(buf[HEADER_LEN : HEADER_LEN + plen])
    if ty == TY_ASSIGN:
        f = {k: c.u64() for k in ("frame_id", "shard_id", "bin0", "nbins", "row0", "nrows", "img_h", "img_w")}
        f["img_path"], f["out_path"] = c.string(), c.string()
        if version >= 2:
            f["plane"] = c.take(1)[0]
            f["slot"], f["slot_off"], f["ring_bytes"] = c.u64(), c.u64(), c.u64()
            f["ring_path"] = c.string()
        else:
            # v1 peers only speak the spill-file plane.
            f["plane"], f["slot"], f["slot_off"], f["ring_bytes"] = PLANE_FILE, 0, 0, 0
            f["ring_path"] = ""
        if version >= 3:
            f["deadline_us"], f["strip_checksum"] = c.u64(), c.u32()
        else:
            # v1/v2 peers carry no deadline budget and no strip sum.
            f["deadline_us"], f["strip_checksum"] = 0, 0
        if f["nbins"] == 0 or f["nrows"] == 0 or f["img_h"] == 0 or f["img_w"] == 0:
            raise ProtocolError("malformed", "degenerate shard geometry")
        if f["row0"] + f["nrows"] > f["img_h"]:
            raise ProtocolError("malformed", "shard strip past image")
        # The strip/partial sizes drive buffer allocation on both ends;
        # the Rust side computes them with checked u64 arithmetic
        # (WireAssign::strip_bytes / partial_bytes).
        strip = f["nrows"] * f["img_w"] * 4
        partial = f["nbins"] * f["nrows"] * f["img_w"] * 4
        if f["plane"] == PLANE_FILE:
            pass
        elif f["plane"] == PLANE_STREAM:
            if version < 3:
                raise ProtocolError("malformed", "stream plane needs protocol v3")
            if strip >= U64 or partial >= U64:
                raise ProtocolError("malformed", "stream payload size overflows")
        elif f["plane"] == PLANE_SHM:
            # Hostile slot geometry never reaches the mmap: the strip
            # plus the partial written back in place must fit the slot
            # region inside the advertised ring (protocol.rs decode).
            if not f["ring_path"]:
                raise ProtocolError("malformed", "shm assign without a ring path")
            if strip + partial + f["slot_off"] > f["ring_bytes"]:
                raise ProtocolError("malformed", "shm slot region past ring")
        else:
            raise ProtocolError("malformed", f"data plane byte {f['plane']}")
        msg = ("assign", f)
    elif ty == TY_DONE:
        fid, sid, us, ck = c.u64(), c.u64(), c.u64(), c.u32()
        slot = c.u64() if version >= 2 else NO_SLOT
        msg = ("done", {"frame_id": fid, "shard_id": sid, "kernel_time_us": us,
                        "checksum": ck, "slot": slot})
    elif ty == TY_FAILED:
        fid, sid = c.u64(), c.u64()
        pb = c.take(1)[0]
        if pb not in (0, 1):
            raise ProtocolError("malformed", f"bool byte {pb}")
        reason = c.string()
        if version >= 3:
            db = c.take(1)[0]
            if db not in (0, 1):
                raise ProtocolError("malformed", f"bool byte {db}")
        else:
            db = 0  # pre-v3 peers never deadline-skip
        msg = ("failed", {"frame_id": fid, "shard_id": sid, "panicked": pb == 1,
                          "deadline": db == 1, "reason": reason})
    elif ty == TY_CHUNK and version >= 3:
        fid, sid = c.u64(), c.u64()
        d = c.take(1)[0]
        if d > 1:
            raise ProtocolError("malformed", f"chunk dir byte {d}")
        offset, total, dlen = c.u64(), c.u64(), c.u32()
        if dlen > CHUNK_DATA_MAX:
            raise ProtocolError("malformed", f"chunk data {dlen} B")
        data = c.take(dlen)
        # A chunk past its declared total is corrupt framing (the
        # Rust side also treats offset+len overflow as malformed —
        # with bignums the comparison subsumes it, total < 2^64).
        if offset + dlen > total:
            raise ProtocolError("malformed", "chunk past declared total")
        msg = ("chunk", {"frame_id": fid, "shard_id": sid, "dir": d, "offset": offset,
                         "total": total, "data": bytes(data)})
    elif ty == TY_HELLO and version >= 3:
        hver = struct.unpack("<H", c.take(2))[0]
        msg = ("hello", {"version": hver, "caps": c.u32(), "tag": c.string()})
    elif ty == TY_HEARTBEAT:
        msg = ("heartbeat", {"seq": c.u64()})
    elif ty == TY_CALIBRATION:
        f = {"memcpy_bps": c.f64()}
        f["tile_throughput"] = [c.f64() for _ in range(4)]
        f["tile_throughput_tuned"] = [c.f64() for _ in range(4)]
        f["dispatch_overhead_s"], f["spill_read_latency_s"], f["spill_read_bps"] = c.f64(), c.f64(), c.f64()
        f["samples"] = c.u64()
        msg = ("calibration", f)
    elif ty == TY_SHUTDOWN:
        msg = ("shutdown", {})
    else:
        raise ProtocolError("unknown_type", str(ty))
    c.done()
    return msg, HEADER_LEN + plen


def samples():
    return [
        # File-plane assign (slot fields zeroed, as the Rust encoder
        # emits them), an shm assign mirroring protocol.rs's
        # shm_assign sample (slot 1 of a 2x16 KiB ring), and a
        # stream-plane assign carrying a deadline budget + strip sum.
        ("assign", {"frame_id": 7, "shard_id": 3, "bin0": 8, "nbins": 4, "row0": 16, "nrows": 10,
                    "img_h": 64, "img_w": 48, "img_path": "/tmp/img.bin", "out_path": "/tmp/out-7-3.bin",
                    "plane": PLANE_FILE, "slot": 0, "slot_off": 0, "ring_bytes": 0, "ring_path": "",
                    "deadline_us": 0, "strip_checksum": 0}),
        ("assign", {"frame_id": 7, "shard_id": 4, "bin0": 8, "nbins": 4, "row0": 16, "nrows": 10,
                    "img_h": 64, "img_w": 48, "img_path": "", "out_path": "",
                    "plane": PLANE_SHM, "slot": 1, "slot_off": 16384, "ring_bytes": 32768,
                    "ring_path": "/dev/shm/inthist-shm-1-n0.ring",
                    "deadline_us": 0, "strip_checksum": 0}),
        ("assign", {"frame_id": 7, "shard_id": 5, "bin0": 8, "nbins": 4, "row0": 16, "nrows": 10,
                    "img_h": 64, "img_w": 48, "img_path": "", "out_path": "",
                    "plane": PLANE_STREAM, "slot": 0, "slot_off": 0, "ring_bytes": 0, "ring_path": "",
                    "deadline_us": 250_000, "strip_checksum": 0xBEEFCAFE}),
        ("done", {"frame_id": 7, "shard_id": 3, "kernel_time_us": 1234, "checksum": 0xDEAD,
                  "slot": NO_SLOT}),
        ("done", {"frame_id": 7, "shard_id": 4, "kernel_time_us": 987, "checksum": 0xBEEF,
                  "slot": 1}),
        ("failed", {"frame_id": 7, "shard_id": 3, "panicked": True, "deadline": False,
                    "reason": "injected"}),
        ("failed", {"frame_id": 7, "shard_id": 5, "panicked": False, "deadline": True,
                    "reason": "deadline budget expired before compute"}),
        ("chunk", {"frame_id": 7, "shard_id": 5, "dir": 1, "offset": 512, "total": 1024,
                   "data": bytes(range(256)) * 2}),
        ("hello", {"version": VERSION, "caps": CAPS_ALL, "tag": "proc-worker"}),
        ("heartbeat", {"seq": 42}),
        ("calibration", {"memcpy_bps": 6.0e9, "tile_throughput": [1e8, 2e8, 3e8, 4e8],
                         "tile_throughput_tuned": [1.5e8, 2.5e8, 3.5e8, 4.5e8],
                         "dispatch_overhead_s": 2e-5, "spill_read_latency_s": 1e-4,
                         "spill_read_bps": 4e8, "samples": 3}),
        ("shutdown", {}),
    ]


def test_roundtrip_every_type():
    stream = b""
    for msg in samples():
        wire = encode(msg)
        back, used = decode(wire)
        assert back == msg and used == len(wire), msg[0]
        stream += wire
    # Back-to-back frames decode in order off one buffer.
    off = 0
    for want in samples():
        got, used = decode(stream[off:])
        assert got == want
        off += used
    assert off == len(stream)
    print("framing: every message type round-trips byte-exact, frames stream")


def test_every_truncation_point_is_typed():
    for msg in samples():
        wire = encode(msg)
        for cut in range(len(wire)):
            try:
                decode(wire[:cut])
                raise AssertionError(f"{msg[0]} decoded from {cut}/{len(wire)} bytes")
            except ProtocolError as e:
                assert e.kind in ("truncated", "malformed"), (msg[0], cut, e.kind)
    print("framing: truncation at every byte prefix is a typed error")


def test_header_corruptions_are_typed():
    good = encode(("heartbeat", {"seq": 1}))
    cases = [
        (b"\xff" + good[1:], "bad_magic"),
        (good[:2] + b"\x63\x00" + good[4:], "version_mismatch"),
        (good[:4] + b"\xc8" + good[5:], "unknown_type"),
        (good[:5] + struct.pack("<I", MAX_PAYLOAD + 1) + good[9:], "oversized"),
        (good[:5] + struct.pack("<I", 9) + good[9:] + b"\x00", "malformed"),  # trailing byte
    ]
    for wire, kind in cases:
        try:
            decode(wire)
            raise AssertionError(f"expected {kind}")
        except ProtocolError as e:
            assert e.kind == kind, (kind, e.kind)
    # Degenerate geometry is rejected at decode, not trusted downstream.
    a = dict(samples()[0][1])
    a["nbins"] = 0
    try:
        decode(encode(("assign", a)))
        raise AssertionError("degenerate geometry decoded")
    except ProtocolError as e:
        assert e.kind == "malformed"
    a["nbins"], a["row0"] = 2, 60  # row0+nrows=70 > img_h=64
    try:
        decode(encode(("assign", a)))
        raise AssertionError("strip past image decoded")
    except ProtocolError as e:
        assert e.kind == "malformed"
    print("framing: magic/version/type/length/geometry corruption all typed")


def test_old_version_frames_still_decode():
    # The shm tail (v2) and the remote tail (v3) are MINOR version
    # bumps: a v1 peer's frames must still decode, landing on the
    # spill-file plane with no slot and no deadline.
    a = dict(samples()[0][1])
    wire = encode(("assign", a), version=1)
    assert len(wire) < len(encode(("assign", a), version=2)) < len(encode(("assign", a)))
    got, used = decode(wire)
    assert used == len(wire)
    assert got[1]["plane"] == PLANE_FILE and got[1]["ring_path"] == ""
    assert got[1]["slot"] == 0 and got[1]["slot_off"] == 0 and got[1]["ring_bytes"] == 0
    assert got[1]["img_path"] == a["img_path"] and got[1]["out_path"] == a["out_path"]
    assert got[1]["deadline_us"] == 0 and got[1]["strip_checksum"] == 0
    # A v2 peer's shm assign keeps its slot geometry; the v3 fields
    # default (no deadline, no strip sum).
    shm = dict(samples()[1][1])
    got, _ = decode(encode(("assign", shm), version=2))
    assert got[1]["plane"] == PLANE_SHM and got[1]["slot"] == shm["slot"]
    assert got[1]["deadline_us"] == 0 and got[1]["strip_checksum"] == 0
    d = {"frame_id": 9, "shard_id": 1, "kernel_time_us": 55, "checksum": 0xF00D}
    got, _ = decode(encode(("done", d), version=1))
    assert got[1]["slot"] == NO_SLOT, "v1 done carries no slot to release"
    # Pre-v3 ShardFailed has no deadline byte: never a deadline skip.
    fl = {"frame_id": 9, "shard_id": 1, "panicked": False, "deadline": True, "reason": "x"}
    for v in (1, 2):
        got, _ = decode(encode(("failed", fl), version=v))
        assert got[1]["deadline"] is False, "pre-v3 peers cannot deadline-skip"
    # Chunk and Hello are v3 frame types: under a pre-v3 header the
    # type byte is unknown, not silently misparsed.
    for msg in (samples()[7], samples()[8]):
        assert msg[0] in ("chunk", "hello"), "sample order moved"
        try:
            decode(encode(msg, version=2))
            raise AssertionError(f"{msg[0]} decoded under a v2 header")
        except ProtocolError as e:
            assert e.kind == "unknown_type", (msg[0], e.kind)
    # Versions PAST ours are still refused — only older minors decode.
    future = encode(("heartbeat", {"seq": 1}))
    future = future[:2] + struct.pack("<H", VERSION + 1) + future[4:]
    try:
        decode(future)
        raise AssertionError("future version decoded")
    except ProtocolError as e:
        assert e.kind == "version_mismatch"
    print("framing: v1/v2 frames decode with defaulted tails; future versions refused")


def test_hostile_slot_geometry_is_typed():
    shm = dict(samples()[1][1])
    hostile = [
        dict(shm, ring_bytes=1024),          # slot region past the ring
        dict(shm, slot_off=(1 << 63)),       # offset overflows the region sum
        dict(shm, ring_path=""),             # shm plane without a ring
        dict(shm, plane=7),                  # unknown data-plane byte
    ]
    for a in hostile:
        try:
            decode(encode(("assign", a)))
            raise AssertionError(f"hostile slot geometry decoded: {a}")
        except ProtocolError as e:
            assert e.kind == "malformed", (a, e.kind)
    # The in-bounds shm sample itself round-trips — validation rejects
    # hostile geometry, not the plane.
    back, _ = decode(encode(("assign", shm)))
    assert back == ("assign", shm)
    print("framing: hostile slot geometry (past-ring/ringless/bad plane) all typed")


def test_stream_assign_validation():
    stream = dict(samples()[2][1])
    assert stream["plane"] == PLANE_STREAM, "sample order moved"
    # In-bounds stream assign round-trips with its budget and strip sum.
    back, _ = decode(encode(("assign", stream)))
    assert back == ("assign", stream)
    # The stream plane did not exist before v3: a v2 header claiming it
    # is malformed, not trusted.
    try:
        decode(encode(("assign", stream), version=2))
        raise AssertionError("stream plane decoded under a v2 header")
    except ProtocolError as e:
        assert e.kind == "malformed"
    # Strip/partial byte counts that overflow u64 would poison buffer
    # allocation on both ends — rejected at decode.
    huge = dict(stream, nrows=1 << 62, img_h=1 << 62, row0=0)
    try:
        decode(encode(("assign", huge)))
        raise AssertionError("overflowing stream geometry decoded")
    except ProtocolError as e:
        assert e.kind == "malformed"
    print("framing: stream assign validated (v3-only plane, size overflow typed)")


def test_hostile_chunk_geometry_is_typed():
    chunk = dict(samples()[7][1])
    hostile = [
        dict(chunk, dir=2),                         # unknown direction byte
        dict(chunk, offset=1024),                   # offset+len past declared total
        dict(chunk, offset=U64 - 1, total=U64 - 1), # offset+len overflows u64
        dict(chunk, total=len(chunk["data"]) - 1),  # data alone past total
        dict(chunk, offset=0, total=CHUNK_DATA_MAX + 9,
             data=bytes(CHUNK_DATA_MAX + 1)),       # data above the chunk cap
    ]
    for a in hostile:
        try:
            decode(encode(("chunk", a)))
            raise AssertionError(f"hostile chunk decoded: dir={a['dir']} off={a['offset']}")
        except ProtocolError as e:
            assert e.kind == "malformed", e.kind
    # Boundary cases that MUST decode: a final chunk ending exactly at
    # total, an empty keepalive-shaped chunk, and a max-size chunk.
    for a in (dict(chunk, offset=512, data=bytes(512)),
              dict(chunk, offset=0, data=b""),
              dict(chunk, offset=0, total=CHUNK_DATA_MAX, data=bytes(CHUNK_DATA_MAX))):
        back, _ = decode(encode(("chunk", a)))
        assert back == ("chunk", a)
    print("framing: hostile chunk geometry (dir/overrun/overflow/cap) all typed")


class StreamRx:
    """Mirror of the chunk reassembly rule shared by supervisor.rs
    (stream_rx, partials child→parent) and worker.rs (PendingStream,
    strips parent→child): chunks append dense and in order; a gap,
    replay or overrun drops the buffer — the shard retries typed
    instead of computing on torn bytes."""

    def __init__(self, total):
        self.total = total
        self.buf = bytearray()
        self.dead = False

    def push(self, offset, data):
        in_order = (offset == len(self.buf)
                    and len(data) <= CHUNK_DATA_MAX
                    and len(self.buf) + len(data) <= self.total)
        if not in_order:
            self.dead = True
            return False
        self.buf += data
        return True

    def complete(self):
        return not self.dead and len(self.buf) == self.total


def test_chunk_reassembly_is_dense_in_order_or_dead():
    payload = bytes((i * 37) & 0xFF for i in range(3 * CHUNK_DATA_MAX // 2))
    rx = StreamRx(len(payload))
    for off in range(0, len(payload), CHUNK_DATA_MAX):
        assert rx.push(off, payload[off:off + CHUNK_DATA_MAX])
    assert rx.complete() and bytes(rx.buf) == payload
    assert fnv1a32(rx.buf) == fnv1a32(payload), "reassembly is byte-exact"
    # A gap (skipped chunk), a replay (stale offset) and an overrun
    # (bytes past the declared total) each kill the buffer for good.
    for bad_off, n in ((CHUNK_DATA_MAX, 16), (0, 16), (0, 32)):
        rx = StreamRx(24)
        rx.push(0, bytes(8))
        if bad_off == 0 and n == 32:
            assert not rx.push(8, bytes(n)), "overrun past total must be rejected"
        else:
            assert not rx.push(bad_off if bad_off else 4, bytes(n)), "gap/replay rejected"
        assert rx.dead and not rx.complete()
    # Truncation is not completion: a dense prefix short of total never
    # reads as done (the ShardDone handler checks exact length).
    rx = StreamRx(64)
    rx.push(0, bytes(32))
    assert not rx.complete()
    print("stream plane: chunk reassembly byte-exact; gap/replay/overrun kill the buffer")


def deadline_budget_us(now_us, expires_us):
    """Mirror of supervisor.rs pump(): the deadline crosses the process
    (and host) boundary as *remaining budget* in micros — an Instant is
    meaningless in another clock domain.  0 is the no-deadline
    sentinel; the expired case is dropped pre-dispatch, so a dispatched
    budget clamps to >= 1."""
    if expires_us is None:
        return 0
    return max(expires_us - now_us, 1)


def worker_deadline_expired(deadline_us, elapsed_since_arrival_us):
    """Mirror of worker.rs deadline_expired(): the budget is anchored
    at the assignment's ARRIVAL — the only instant both clock domains
    agree on, because the worker observed it."""
    return deadline_us > 0 and elapsed_since_arrival_us >= deadline_us


def test_deadline_crosses_clock_domains_as_budget():
    # No deadline → the 0 sentinel, which never expires.
    assert deadline_budget_us(1_000, None) == 0
    assert not worker_deadline_expired(0, 10**12)
    # A live budget is the remaining micros at dispatch.
    assert deadline_budget_us(1_000, 251_000) == 250_000
    # Already-expired frames are dropped pre-dispatch; if one races the
    # clamp, >= 1 keeps it distinct from the sentinel (the worker then
    # skips it immediately instead of computing forever).
    assert deadline_budget_us(999_999, 500) == 1
    # The worker re-anchors at arrival: clock skew between the hosts is
    # irrelevant, only transfer+queue time burns the budget.
    assert not worker_deadline_expired(250_000, 100_000)
    assert worker_deadline_expired(250_000, 250_000)
    assert worker_deadline_expired(1, 1)
    print("deadline: budget-at-dispatch encoding, worker re-anchors at arrival")


def test_random_bytes_never_crash_the_decoder():
    # xorshift-ish deterministic garbage, half with a valid header so
    # the payload decoders get fuzzed too (mirror of the Rust fuzz).
    state = 0x9E3779B97F4A7C15
    for trial in range(500):
        state = (state * 6364136223846793005 + 1442695040888963407) & ((1 << 64) - 1)
        n = state % 64
        buf = bytearray((state >> (8 * (i % 8))) & 0xFF for i in range(n))
        if trial % 2 == 0 and len(buf) >= HEADER_LEN:
            buf[0:4] = struct.pack("<HH", MAGIC, VERSION)
            buf[4] = (state % 8) + 1
            buf[5:9] = struct.pack("<I", len(buf) - HEADER_LEN)
        try:
            decode(bytes(buf))
        except ProtocolError:
            pass  # typed is the contract; any other exception propagates
    print("framing: 500 garbage buffers decoded or rejected typed, no crash")


def test_checksum_stable_and_bit_sensitive():
    data = [1.0, 2.0, 3.5, -0.0]
    a = checksum_f32(data)
    assert a == checksum_f32(data)
    flipped = list(data)
    flipped[2] = struct.unpack("<f", struct.pack("<I", struct.unpack("<I", struct.pack("<f", 3.5))[0] + 1))[0]
    assert checksum_f32(flipped) != a, "one mantissa step must change the sum"
    assert checksum_f32([]) == 0x811C9DC5, "empty input is the FNV basis"
    print("checksum: deterministic, bit-sensitive, basis on empty input")


class SupervisorSim:
    """Deterministic mirror of ProcSupervisor's dispatcher: pending
    queue, per-child in-flight maps, the requeue ladder, the
    at-most-once frame-failure discipline, the per-child shm slot ring
    (`ring_slots` > 0 enables it) and the boot-deferred heartbeat
    watchdog.  Time is an integer tick."""

    def __init__(self, workers=2, max_attempts=3, per_child_inflight=2, heartbeat_timeout=10,
                 ring_slots=0, remote=(), reconnect_attempts=3):
        self.max_attempts = max_attempts
        self.cap = per_child_inflight
        self.hb_timeout = heartbeat_timeout
        self.ring_slots = ring_slots
        self.reconnect_attempts = reconnect_attempts
        # Per-attempt outcomes for remote reconnects, consumed front to
        # back; exhausted plan means the endpoint accepts (the chaos
        # schedule, mirror of fault_property.rs's proxy).
        self.reconnect_plan = deque()
        self.now = 0
        # Remote slots start `spoken`: the Hello handshake already
        # proved the peer talks (supervisor.rs connect_slot).
        self.slots = [{"alive": True, "inflight": {}, "last_seen": 0,
                       "spoken": i in remote, "spawned_at": 0, "averted": False,
                       "remote": i in remote}
                      for i in range(workers)]
        # Rings OUTLIVE their child: a replacement child remaps the same
        # ring file, so in-use slots must be reclaimed on reap or the
        # ring leaks capacity (supervisor.rs reap path).
        self.rings = [set() for _ in range(workers)]
        self.pending = deque()
        self.frames = {}
        self.stats = {"dispatched": 0, "requeued": 0, "completed": 0, "shard_failures": 0,
                      "respawns": 0, "skipped_deadline": 0, "img_deleted": [], "typed_failures": [],
                      "shm_dispatched": 0, "shm_fallbacks": 0, "slots_reclaimed": 0,
                      "kills_averted": 0, "remote_reconnects": 0, "skipped_deadline_worker": 0}

    def submit(self, frame_id, nshards, expires=None):
        self.frames[frame_id] = {"outstanding": nshards, "failed": False, "expires": expires,
                                 "results": []}
        for sid in range(nshards):
            self.pending.append({"frame": frame_id, "shard": sid, "attempts": 0})

    def _retire(self, frame_id):
        f = self.frames[frame_id]
        f["outstanding"] -= 1
        assert f["outstanding"] >= 0, "retire underflow"
        if f["outstanding"] == 0:
            # Outstanding-zero cleanup: the frame's image spill file is
            # deleted exactly once (supervisor.rs retire()).
            self.stats["img_deleted"].append(frame_id)
            del self.frames[frame_id]

    def _fail_frame(self, frame_id, error):
        f = self.frames.get(frame_id)
        if f is None or f["failed"]:
            return  # at-most-once: later shard outcomes stay silent
        f["failed"] = True
        self.stats["typed_failures"].append((frame_id, error))

    def _retry_or_fail(self, task, reason):
        task["attempts"] += 1
        if task["attempts"] >= self.max_attempts:
            self.stats["shard_failures"] += 1
            self._fail_frame(task["frame"], reason)
            self._retire(task["frame"])
        else:
            self.stats["requeued"] += 1
            self.pending.append(task)

    def pump(self):
        progressed = True
        while progressed and self.pending:
            progressed = False
            task = self.pending[0]
            f = self.frames.get(task["frame"])
            if f is None:
                self.pending.popleft()
                progressed = True
                continue
            if f["failed"]:
                self.pending.popleft()
                self._retire(task["frame"])
                progressed = True
                continue
            if f["expires"] is not None and self.now >= f["expires"]:
                # Deadline satellite: dropped BEFORE dispatch.
                self.pending.popleft()
                self.stats["skipped_deadline"] += 1
                self._fail_frame(task["frame"], "deadline")
                self._retire(task["frame"])
                progressed = True
                continue
            candidates = [i for i, s in enumerate(self.slots)
                          if s["alive"] and len(s["inflight"]) < self.cap]
            if not any(s["alive"] for s in self.slots):
                self.pending.popleft()
                self._fail_frame(task["frame"], "workers_gone")
                self._retire(task["frame"])
                progressed = True
                continue
            if not candidates:
                return  # every live child saturated; head-of-line waits
            node = min(candidates, key=lambda i: len(self.slots[i]["inflight"]))
            self.pending.popleft()
            if self.ring_slots:
                free = set(range(self.ring_slots)) - self.rings[node]
                if free:
                    task["slot"] = min(free)
                    self.rings[node].add(task["slot"])
                    self.stats["shm_dispatched"] += 1
                else:
                    # Ring full: this shard rides the spill-file plane
                    # rather than blocking the dispatcher.
                    task["slot"] = None
                    self.stats["shm_fallbacks"] += 1
            self.slots[node]["inflight"][(task["frame"], task["shard"])] = task
            self.stats["dispatched"] += 1
            progressed = True

    def _free_slot(self, node, task):
        slot = task.pop("slot", None)
        if slot is not None:
            self.rings[node].discard(slot)

    def child_dies(self, node):
        """SIGKILL / dropped-link analog: reclaim its ring slots,
        requeue everything in flight, then respawn (local) or
        re-connect under the bounded ladder (remote)."""
        s = self.slots[node]
        assert s["alive"]
        s["alive"] = False
        remote = s["remote"]
        orphans = list(s["inflight"].values())
        s["inflight"] = {}
        # Reclaim-on-reap: a SIGKILLed child never sends ShardDone for
        # its in-flight slots, so the reaper releases them before the
        # replacement spawns — counted, so tests can assert it fired.
        reclaimed = len(self.rings[node])
        if reclaimed:
            self.stats["slots_reclaimed"] += reclaimed
            self.rings[node] = set()
        for t in orphans:
            t.pop("slot", None)  # the reaper already released it
            self._retry_or_fail(t, "worker process died")
        if remote:
            # The reconnect ladder (supervisor.rs child_died, remote
            # arm): bounded attempts; exhaustion leaves the slot DEAD —
            # pump() then fails frames typed instead of hanging.
            for _ in range(self.reconnect_attempts):
                ok = self.reconnect_plan.popleft() if self.reconnect_plan else True
                if ok:
                    self.slots[node] = {"alive": True, "inflight": {}, "last_seen": self.now,
                                        "spoken": True, "spawned_at": self.now,
                                        "averted": False, "remote": True}
                    self.stats["remote_reconnects"] += 1
                    self.stats["respawns"] += 1
                    return
            return  # ladder exhausted: slot stays dead
        self.slots[node] = {"alive": True, "inflight": {}, "last_seen": self.now,
                            "spoken": False, "spawned_at": self.now, "averted": False,
                            "remote": False}
        self.stats["respawns"] += 1

    def heartbeat(self, node):
        self.slots[node]["last_seen"] = self.now
        self.slots[node]["spoken"] = True

    def check_heartbeats(self):
        # Boot false-kill fix: heartbeat age is only enforced once the
        # child has SPOKEN — a slow boot (calibration, cold binary) is
        # not a hang.  The backstop: a child silent past 10x the
        # timeout without ever speaking is truly hung and still dies.
        boot_grace = self.hb_timeout * 10
        for i, s in enumerate(self.slots):
            if s["alive"] and self.now - s["last_seen"] > self.hb_timeout:
                if not s["spoken"] and self.now - s["spawned_at"] <= boot_grace:
                    if not s["averted"]:
                        s["averted"] = True
                        self.stats["kills_averted"] += 1
                    continue
                self.child_dies(i)

    def complete(self, node, frame_id, shard_id, ok=True, reason="", deadline_skip=False):
        task = self.slots[node]["inflight"].pop((frame_id, shard_id))
        self.heartbeat(node)  # any message refreshes liveness
        self._free_slot(node, task)  # slot freed on EVERY outcome path
        f = self.frames.get(frame_id)
        if f is None:
            return
        if f["failed"]:
            self._retire(frame_id)
            return
        if deadline_skip:
            # ShardFailed{deadline:true}: the worker's remaining-budget
            # clock ran out after dispatch.  That is the frame's
            # deadline expiring, not a compute fault — typed, charged
            # to its own counter, and NO retry attempt burned (a retry
            # would only be later).  Mirror of supervisor.rs handle().
            self.stats["skipped_deadline_worker"] += 1
            self._fail_frame(frame_id, "deadline")
            self._retire(frame_id)
            return
        if ok:
            self.stats["completed"] += 1
            f["results"].append(shard_id)
            self._retire(frame_id)
        else:
            self._retry_or_fail(task, reason)

    def drain_inflight(self):
        return [(i, k) for i, s in enumerate(self.slots) for k in s["inflight"]]


def test_child_death_requeues_and_frame_completes():
    sim = SupervisorSim(workers=2, max_attempts=3, per_child_inflight=2)
    sim.submit(1, 4)
    sim.pump()
    assert sim.stats["dispatched"] == 4, "2 children x cap 2"
    victim_inflight = [k for (n, k) in sim.drain_inflight() if n == 0]
    assert victim_inflight, "child 0 must hold work"
    sim.child_dies(0)
    assert sim.stats["requeued"] == len(victim_inflight), "every orphan requeued, attempts+1"
    sim.pump()  # replacement picks the orphans back up
    for node, (fid, sid) in sim.drain_inflight():
        sim.complete(node, fid, sid)
    assert sim.pending == deque() and not sim.drain_inflight()
    assert sim.stats["completed"] == 4 and sim.stats["shard_failures"] == 0
    assert sim.stats["img_deleted"] == [1], "outstanding-zero cleanup fired exactly once"
    assert sim.stats["typed_failures"] == [], "a survivable kill fails nothing"
    assert 1 not in sim.frames
    print("supervision: child death requeues orphans; frame completes, cleanup once")


def test_attempt_exhaustion_fails_frame_exactly_once():
    sim = SupervisorSim(workers=1, max_attempts=2, per_child_inflight=4)
    sim.submit(5, 3)
    sim.pump()
    # Shard 0 fails both its attempts; shards 1-2 also report failures
    # afterwards — the frame error must still be recorded exactly once.
    sim.complete(0, 5, 0, ok=False, reason="compute failed")
    sim.pump()
    sim.complete(0, 5, 0, ok=False, reason="compute failed")  # attempt 2 of 2
    assert sim.stats["shard_failures"] == 1
    assert len(sim.stats["typed_failures"]) == 1, "typed failure is at-most-once"
    sim.complete(0, 5, 1, ok=False, reason="compute failed")
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert len(sim.stats["typed_failures"]) == 1, "later outcomes stay silent"
    assert sim.stats["img_deleted"] == [5], "failed frames still clean up exactly once"
    assert 5 not in sim.frames and not sim.pending
    print("supervision: attempts ladder bounds retries; frame fails typed exactly once")


def test_heartbeat_timeout_is_a_death():
    sim = SupervisorSim(workers=2, max_attempts=3, heartbeat_timeout=5)
    sim.submit(9, 4)
    sim.pump()
    sim.now = 1
    sim.heartbeat(0)  # both children boot and speak...
    sim.heartbeat(1)
    sim.now = 7
    sim.heartbeat(1)  # ...then child 0 goes dark; child 1 stays chatty
    sim.check_heartbeats()
    assert sim.stats["respawns"] == 1, "only the silent child is declared dead"
    assert sim.stats["kills_averted"] == 0, "post-boot silence is never an aversion"
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert sim.stats["completed"] == 4 and sim.stats["typed_failures"] == []
    print("supervision: heartbeat silence past the timeout = child death + requeue")


def test_booting_child_is_spared_until_first_message():
    # The false-kill bug: a child still calibrating has sent NOTHING, so
    # its heartbeat age is its spawn age — the old watchdog killed it.
    sim = SupervisorSim(workers=2, heartbeat_timeout=5)
    sim.submit(11, 4)
    sim.pump()
    sim.now = 6
    sim.heartbeat(1)  # child 1 booted fast; child 0 has never spoken
    sim.check_heartbeats()
    assert sim.stats["respawns"] == 0, "silent boot must be spared, not reaped"
    assert sim.stats["kills_averted"] == 1
    sim.now = 12
    sim.heartbeat(1)
    sim.check_heartbeats()
    assert sim.stats["kills_averted"] == 1, "the aversion is counted once per boot"
    # First message starts enforcement: speak at 20, dark again by 26.
    sim.now = 20
    sim.heartbeat(0)
    sim.now = 26
    sim.heartbeat(1)
    sim.check_heartbeats()
    assert sim.stats["respawns"] == 1, "post-boot silence is still a death"
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert sim.stats["completed"] == 4 and sim.stats["typed_failures"] == []
    # Backstop: a child that NEVER speaks past 10x the timeout is a
    # genuine hang and still dies.
    sim2 = SupervisorSim(workers=1, heartbeat_timeout=5)
    sim2.submit(12, 1)
    sim2.pump()
    sim2.now = 50
    sim2.check_heartbeats()
    assert sim2.stats["respawns"] == 0, "within boot grace: spared"
    sim2.now = 51
    sim2.check_heartbeats()
    assert sim2.stats["respawns"] == 1, "past boot grace: a hung boot is reaped"
    print("supervision: heartbeat enforcement deferred to first message, graced backstop")


def test_ring_slots_released_on_completion_and_reclaimed_on_reap():
    sim = SupervisorSim(workers=2, per_child_inflight=2, ring_slots=2)
    sim.submit(21, 6)
    sim.pump()
    assert sim.stats["shm_dispatched"] == 4, "2 children x 2 ring slots in flight"
    held = len(sim.rings[0])
    assert held == 2, "child 0's ring is fully loaded"
    sim.child_dies(0)
    assert sim.stats["slots_reclaimed"] == held, "reap reclaims every in-flight slot"
    assert sim.rings[0] == set(), "the replacement starts with an empty ring"
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert sim.stats["completed"] == 6 and sim.stats["typed_failures"] == []
    assert all(not r for r in sim.rings), "every slot released once drained"
    print("supervision: ring slots released on completion, reclaimed on reap")


def test_full_ring_falls_back_to_the_file_plane():
    # inflight cap 3 > ring capacity 1: the third dispatch to a child
    # finds no free slot and must ride the spill-file plane instead of
    # wedging the dispatcher.
    sim = SupervisorSim(workers=1, per_child_inflight=3, ring_slots=1)
    sim.submit(31, 3)
    sim.pump()
    assert sim.stats["dispatched"] == 3
    assert sim.stats["shm_dispatched"] == 1 and sim.stats["shm_fallbacks"] == 2
    for node, (fid, sid) in sim.drain_inflight():
        sim.complete(node, fid, sid)
    assert sim.stats["completed"] == 3 and not sim.rings[0]
    print("supervision: a full ring degrades to the file plane, never deadlocks")


def test_expired_deadline_drops_before_dispatch():
    sim = SupervisorSim(workers=2)
    sim.now = 100
    sim.submit(3, 5, expires=50)  # already blown at submit
    before = sim.stats["dispatched"]
    sim.pump()
    assert sim.stats["dispatched"] == before, "expired shards never reach a child"
    # The first expired shard fails the frame; its siblings then retire
    # through the at-most-once failed branch (supervisor.rs pump()).
    assert sim.stats["skipped_deadline"] == 1
    assert [f for (f, e) in sim.stats["typed_failures"]] == [3] and \
        sim.stats["typed_failures"][0][1] == "deadline"
    assert sim.stats["img_deleted"] == [3] and 3 not in sim.frames
    print("supervision: blown deadline drops the whole frame pre-dispatch, typed once")


def test_remote_disconnect_reconnects_and_completes():
    # Pure-remote pool (mirror of proc_property.rs's loopback test):
    # a dropped link requeues its in-flight shards with attempts+1 and
    # the reconnected slot picks them back up — bit-identical outcome,
    # one reconnect counted.
    sim = SupervisorSim(workers=2, max_attempts=3, per_child_inflight=2, remote=(0, 1))
    sim.submit(41, 4)
    sim.pump()
    assert sim.stats["dispatched"] == 4
    victim = [k for (n, k) in sim.drain_inflight() if n == 0]
    assert victim, "node 0 must hold work"
    sim.reconnect_plan = deque([False, True])  # first attempt refused, second accepts
    sim.child_dies(0)
    assert sim.stats["remote_reconnects"] == 1, "the ladder retried past the refusal"
    assert sim.stats["requeued"] == len(victim), "every orphan burned one attempt"
    assert sim.slots[0]["alive"] and sim.slots[0]["spoken"], \
        "a reconnected link is live and has proven it speaks"
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert sim.stats["completed"] == 4 and sim.stats["typed_failures"] == []
    assert sim.stats["img_deleted"] == [41]
    print("supervision: remote disconnect reconnects under the ladder; frame completes")


def test_remote_reconnect_exhaustion_fails_typed():
    # Every reconnect attempt refused: the slot stays dead and pending
    # shards fail TYPED through workers_gone — never a silent hang
    # (supervisor.rs pump() whole-pool-gone arm).
    sim = SupervisorSim(workers=1, max_attempts=5, per_child_inflight=2,
                        remote=(0,), reconnect_attempts=3)
    sim.submit(43, 3)
    sim.pump()
    sim.reconnect_plan = deque([False] * 8)
    sim.child_dies(0)
    assert sim.stats["remote_reconnects"] == 0 and not sim.slots[0]["alive"]
    assert len(sim.reconnect_plan) == 5, "the ladder stopped at its bound (3 attempts)"
    sim.pump()
    assert [f for (f, e) in sim.stats["typed_failures"]] == [43]
    assert sim.stats["typed_failures"][0][1] == "workers_gone"
    assert sim.stats["img_deleted"] == [43] and 43 not in sim.frames and not sim.pending
    print("supervision: reconnect exhaustion leaves the slot dead; frames fail typed")


def test_worker_deadline_skip_is_typed_and_burns_no_retry():
    # A worker-side deadline skip (budget burned in transfer/queue) is
    # the deadline expiring, not a compute fault: typed exactly once,
    # charged to skipped_deadline_worker, and the shard is NOT requeued
    # — a retry would only finish later.
    sim = SupervisorSim(workers=2, max_attempts=3, remote=(0, 1))
    sim.submit(47, 4)
    sim.pump()
    requeued_before = sim.stats["requeued"]
    (node, (fid, sid)) = sim.drain_inflight()[0]
    sim.complete(node, fid, sid, deadline_skip=True)
    assert sim.stats["skipped_deadline_worker"] == 1
    assert sim.stats["requeued"] == requeued_before, "a deadline skip burns no retry"
    assert [f for (f, e) in sim.stats["typed_failures"]] == [47]
    assert sim.stats["typed_failures"][0][1] == "deadline"
    # Siblings retire silently through the at-most-once failed branch.
    while sim.drain_inflight():
        for node, key in sim.drain_inflight():
            sim.complete(node, key[0], key[1])
        sim.pump()
    sim.pump()
    assert len(sim.stats["typed_failures"]) == 1
    assert sim.stats["img_deleted"] == [47] and 47 not in sim.frames
    print("supervision: worker deadline skip typed once, no retry burned")


if __name__ == "__main__":
    test_roundtrip_every_type()
    test_every_truncation_point_is_typed()
    test_header_corruptions_are_typed()
    test_old_version_frames_still_decode()
    test_hostile_slot_geometry_is_typed()
    test_stream_assign_validation()
    test_hostile_chunk_geometry_is_typed()
    test_chunk_reassembly_is_dense_in_order_or_dead()
    test_deadline_crosses_clock_domains_as_budget()
    test_random_bytes_never_crash_the_decoder()
    test_checksum_stable_and_bit_sensitive()
    test_child_death_requeues_and_frame_completes()
    test_attempt_exhaustion_fails_frame_exactly_once()
    test_heartbeat_timeout_is_a_death()
    test_booting_child_is_spared_until_first_message()
    test_ring_slots_released_on_completion_and_reclaimed_on_reap()
    test_full_ring_falls_back_to_the_file_plane()
    test_expired_deadline_drops_before_dispatch()
    test_remote_disconnect_reconnects_and_completes()
    test_remote_reconnect_exhaustion_fails_typed()
    test_worker_deadline_skip_is_typed_and_burns_no_retry()
    print("proc plane pre-validation: ALL OK")
