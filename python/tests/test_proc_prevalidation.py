"""Pre-validation of the rust/src/proc/ multi-process execution plane,
mirrored in Python (the dev container ships no Rust toolchain; the Rust
side asserts the same invariants in-tree: protocol unit tests in
rust/src/proc/protocol.rs, process-boundary property tests in
rust/tests/proc_property.rs).

1. Framing (mirror of proc::protocol::ProcMsg): byte-exact encode /
   decode of every message type over the
   `[magic u16 LE][version u16 LE][type u8][len u32 LE][payload]`
   wire format; truncation at EVERY byte prefix, foreign magic, version
   skew, unknown types, oversized lengths, trailing payload bytes and
   degenerate shard geometry all land in a typed error — never a crash,
   never a partially-decoded message.
2. Checksum (mirror of proc::protocol::checksum_f32): FNV-1a over f32
   LE bytes — deterministic, bit-sensitive, empty input is the basis.
3. Supervision (mirror of proc::supervisor::ProcSupervisor): a
   deterministic state machine driving dispatch / child death /
   heartbeat timeout proves the requeue ladder — a dead child's
   in-flight shards are requeued with attempts+1 and complete on the
   replacement; a shard that exhausts max_attempts fails its frame
   typed EXACTLY once; the frame's outstanding count drains to zero and
   its image spill file is cleaned up exactly once; an expired deadline
   drops shards before any dispatch.

Run: python3 python/tests/test_proc_prevalidation.py  (or pytest)
"""

import struct
from collections import deque

MAGIC = 0x4948  # "IH"
VERSION = 1
MAX_PAYLOAD = 1 << 20
HEADER_LEN = 9

TY_ASSIGN, TY_DONE, TY_FAILED, TY_HEARTBEAT, TY_CALIBRATION, TY_SHUTDOWN = 1, 2, 3, 4, 5, 6


class ProtocolError(Exception):
    """kind in: truncated, bad_magic, version_mismatch, oversized,
    unknown_type, malformed — the ProtocolError variant surface."""

    def __init__(self, kind, detail=""):
        super().__init__(f"{kind}: {detail}")
        self.kind = kind


def fnv1a32(data):
    """Mirror of proc::protocol::checksum_f32's inner loop (keep in
    sync with shard::store::fnv1a32 — same constants)."""
    h = 0x811C9DC5
    for b in data:
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h


def checksum_f32(values):
    """Mirror of proc::protocol::checksum_f32: FNV-1a over f32 LE bytes."""
    return fnv1a32(struct.pack(f"<{len(values)}f", *values))


def _put_string(out, s):
    b = s.encode("utf-8")
    out += struct.pack("<I", len(b)) + b


def encode(msg):
    """Mirror of ProcMsg::encode — msg is (type_name, fields dict)."""
    ty_name, f = msg
    p = bytearray()
    if ty_name == "assign":
        ty = TY_ASSIGN
        for k in ("frame_id", "shard_id", "bin0", "nbins", "row0", "nrows", "img_h", "img_w"):
            p += struct.pack("<Q", f[k])
        _put_string(p, f["img_path"])
        _put_string(p, f["out_path"])
    elif ty_name == "done":
        ty = TY_DONE
        p += struct.pack("<QQQI", f["frame_id"], f["shard_id"], f["kernel_time_us"], f["checksum"])
    elif ty_name == "failed":
        ty = TY_FAILED
        p += struct.pack("<QQ", f["frame_id"], f["shard_id"])
        p += bytes([1 if f["panicked"] else 0])
        _put_string(p, f["reason"])
    elif ty_name == "heartbeat":
        ty = TY_HEARTBEAT
        p += struct.pack("<Q", f["seq"])
    elif ty_name == "calibration":
        ty = TY_CALIBRATION
        p += struct.pack("<d", f["memcpy_bps"])
        for t in f["tile_throughput"] + f["tile_throughput_tuned"]:
            p += struct.pack("<d", t)
        p += struct.pack("<ddd", f["dispatch_overhead_s"], f["spill_read_latency_s"], f["spill_read_bps"])
        p += struct.pack("<Q", f["samples"])
    elif ty_name == "shutdown":
        ty = TY_SHUTDOWN
    else:
        raise AssertionError(ty_name)
    assert len(p) <= MAX_PAYLOAD
    return struct.pack("<HHBI", MAGIC, VERSION, ty, len(p)) + bytes(p)


class _Cursor:
    def __init__(self, buf):
        self.buf, self.pos = buf, 0

    def take(self, n):
        if self.pos + n > len(self.buf):
            raise ProtocolError("truncated")
        s = self.buf[self.pos : self.pos + n]
        self.pos += n
        return s

    def u32(self):
        return struct.unpack("<I", self.take(4))[0]

    def u64(self):
        return struct.unpack("<Q", self.take(8))[0]

    def f64(self):
        return struct.unpack("<d", self.take(8))[0]

    def string(self):
        n = self.u32()
        if n > MAX_PAYLOAD:
            raise ProtocolError("malformed", f"string length {n}")
        try:
            return self.take(n).decode("utf-8")
        except UnicodeDecodeError:
            raise ProtocolError("malformed", "non-UTF-8 string")

    def done(self):
        if self.pos != len(self.buf):
            raise ProtocolError("malformed", f"{len(self.buf) - self.pos} trailing payload bytes")


def decode(buf):
    """Mirror of ProcMsg::decode: returns (msg, used) or raises a typed
    ProtocolError.  Total over arbitrary bytes."""
    if len(buf) < HEADER_LEN:
        raise ProtocolError("truncated")
    magic, version, ty, plen = struct.unpack("<HHBI", buf[:HEADER_LEN])
    if magic != MAGIC:
        raise ProtocolError("bad_magic", hex(magic))
    if version != VERSION:
        raise ProtocolError("version_mismatch", str(version))
    if plen > MAX_PAYLOAD:
        raise ProtocolError("oversized", str(plen))
    if len(buf) < HEADER_LEN + plen:
        raise ProtocolError("truncated")
    c = _Cursor(buf[HEADER_LEN : HEADER_LEN + plen])
    if ty == TY_ASSIGN:
        f = {k: c.u64() for k in ("frame_id", "shard_id", "bin0", "nbins", "row0", "nrows", "img_h", "img_w")}
        f["img_path"], f["out_path"] = c.string(), c.string()
        if f["nbins"] == 0 or f["nrows"] == 0 or f["img_h"] == 0 or f["img_w"] == 0:
            raise ProtocolError("malformed", "degenerate shard geometry")
        if f["row0"] + f["nrows"] > f["img_h"]:
            raise ProtocolError("malformed", "shard strip past image")
        msg = ("assign", f)
    elif ty == TY_DONE:
        fid, sid, us, ck = c.u64(), c.u64(), c.u64(), c.u32()
        msg = ("done", {"frame_id": fid, "shard_id": sid, "kernel_time_us": us, "checksum": ck})
    elif ty == TY_FAILED:
        fid, sid = c.u64(), c.u64()
        pb = c.take(1)[0]
        if pb not in (0, 1):
            raise ProtocolError("malformed", f"bool byte {pb}")
        msg = ("failed", {"frame_id": fid, "shard_id": sid, "panicked": pb == 1, "reason": c.string()})
    elif ty == TY_HEARTBEAT:
        msg = ("heartbeat", {"seq": c.u64()})
    elif ty == TY_CALIBRATION:
        f = {"memcpy_bps": c.f64()}
        f["tile_throughput"] = [c.f64() for _ in range(4)]
        f["tile_throughput_tuned"] = [c.f64() for _ in range(4)]
        f["dispatch_overhead_s"], f["spill_read_latency_s"], f["spill_read_bps"] = c.f64(), c.f64(), c.f64()
        f["samples"] = c.u64()
        msg = ("calibration", f)
    elif ty == TY_SHUTDOWN:
        msg = ("shutdown", {})
    else:
        raise ProtocolError("unknown_type", str(ty))
    c.done()
    return msg, HEADER_LEN + plen


def samples():
    return [
        ("assign", {"frame_id": 7, "shard_id": 3, "bin0": 8, "nbins": 4, "row0": 16, "nrows": 10,
                    "img_h": 64, "img_w": 48, "img_path": "/tmp/img.bin", "out_path": "/tmp/out-7-3.bin"}),
        ("done", {"frame_id": 7, "shard_id": 3, "kernel_time_us": 1234, "checksum": 0xDEAD}),
        ("failed", {"frame_id": 7, "shard_id": 3, "panicked": True, "reason": "injected"}),
        ("heartbeat", {"seq": 42}),
        ("calibration", {"memcpy_bps": 6.0e9, "tile_throughput": [1e8, 2e8, 3e8, 4e8],
                         "tile_throughput_tuned": [1.5e8, 2.5e8, 3.5e8, 4.5e8],
                         "dispatch_overhead_s": 2e-5, "spill_read_latency_s": 1e-4,
                         "spill_read_bps": 4e8, "samples": 3}),
        ("shutdown", {}),
    ]


def test_roundtrip_every_type():
    stream = b""
    for msg in samples():
        wire = encode(msg)
        back, used = decode(wire)
        assert back == msg and used == len(wire), msg[0]
        stream += wire
    # Back-to-back frames decode in order off one buffer.
    off = 0
    for want in samples():
        got, used = decode(stream[off:])
        assert got == want
        off += used
    assert off == len(stream)
    print("framing: every message type round-trips byte-exact, frames stream")


def test_every_truncation_point_is_typed():
    for msg in samples():
        wire = encode(msg)
        for cut in range(len(wire)):
            try:
                decode(wire[:cut])
                raise AssertionError(f"{msg[0]} decoded from {cut}/{len(wire)} bytes")
            except ProtocolError as e:
                assert e.kind in ("truncated", "malformed"), (msg[0], cut, e.kind)
    print("framing: truncation at every byte prefix is a typed error")


def test_header_corruptions_are_typed():
    good = encode(("heartbeat", {"seq": 1}))
    cases = [
        (b"\xff" + good[1:], "bad_magic"),
        (good[:2] + b"\x63\x00" + good[4:], "version_mismatch"),
        (good[:4] + b"\xc8" + good[5:], "unknown_type"),
        (good[:5] + struct.pack("<I", MAX_PAYLOAD + 1) + good[9:], "oversized"),
        (good[:5] + struct.pack("<I", 9) + good[9:] + b"\x00", "malformed"),  # trailing byte
    ]
    for wire, kind in cases:
        try:
            decode(wire)
            raise AssertionError(f"expected {kind}")
        except ProtocolError as e:
            assert e.kind == kind, (kind, e.kind)
    # Degenerate geometry is rejected at decode, not trusted downstream.
    a = dict(samples()[0][1])
    a["nbins"] = 0
    try:
        decode(encode(("assign", a)))
        raise AssertionError("degenerate geometry decoded")
    except ProtocolError as e:
        assert e.kind == "malformed"
    a["nbins"], a["row0"] = 2, 60  # row0+nrows=70 > img_h=64
    try:
        decode(encode(("assign", a)))
        raise AssertionError("strip past image decoded")
    except ProtocolError as e:
        assert e.kind == "malformed"
    print("framing: magic/version/type/length/geometry corruption all typed")


def test_random_bytes_never_crash_the_decoder():
    # xorshift-ish deterministic garbage, half with a valid header so
    # the payload decoders get fuzzed too (mirror of the Rust fuzz).
    state = 0x9E3779B97F4A7C15
    for trial in range(500):
        state = (state * 6364136223846793005 + 1442695040888963407) & ((1 << 64) - 1)
        n = state % 64
        buf = bytearray((state >> (8 * (i % 8))) & 0xFF for i in range(n))
        if trial % 2 == 0 and len(buf) >= HEADER_LEN:
            buf[0:4] = struct.pack("<HH", MAGIC, VERSION)
            buf[4] = (state % 8) + 1
            buf[5:9] = struct.pack("<I", len(buf) - HEADER_LEN)
        try:
            decode(bytes(buf))
        except ProtocolError:
            pass  # typed is the contract; any other exception propagates
    print("framing: 500 garbage buffers decoded or rejected typed, no crash")


def test_checksum_stable_and_bit_sensitive():
    data = [1.0, 2.0, 3.5, -0.0]
    a = checksum_f32(data)
    assert a == checksum_f32(data)
    flipped = list(data)
    flipped[2] = struct.unpack("<f", struct.pack("<I", struct.unpack("<I", struct.pack("<f", 3.5))[0] + 1))[0]
    assert checksum_f32(flipped) != a, "one mantissa step must change the sum"
    assert checksum_f32([]) == 0x811C9DC5, "empty input is the FNV basis"
    print("checksum: deterministic, bit-sensitive, basis on empty input")


class SupervisorSim:
    """Deterministic mirror of ProcSupervisor's dispatcher: pending
    queue, per-child in-flight maps, the requeue ladder and the
    at-most-once frame-failure discipline.  Time is an integer tick."""

    def __init__(self, workers=2, max_attempts=3, per_child_inflight=2, heartbeat_timeout=10):
        self.max_attempts = max_attempts
        self.cap = per_child_inflight
        self.hb_timeout = heartbeat_timeout
        self.now = 0
        self.slots = [{"alive": True, "inflight": {}, "last_seen": 0} for _ in range(workers)]
        self.pending = deque()
        self.frames = {}
        self.stats = {"dispatched": 0, "requeued": 0, "completed": 0, "shard_failures": 0,
                      "respawns": 0, "skipped_deadline": 0, "img_deleted": [], "typed_failures": []}

    def submit(self, frame_id, nshards, expires=None):
        self.frames[frame_id] = {"outstanding": nshards, "failed": False, "expires": expires,
                                 "results": []}
        for sid in range(nshards):
            self.pending.append({"frame": frame_id, "shard": sid, "attempts": 0})

    def _retire(self, frame_id):
        f = self.frames[frame_id]
        f["outstanding"] -= 1
        assert f["outstanding"] >= 0, "retire underflow"
        if f["outstanding"] == 0:
            # Outstanding-zero cleanup: the frame's image spill file is
            # deleted exactly once (supervisor.rs retire()).
            self.stats["img_deleted"].append(frame_id)
            del self.frames[frame_id]

    def _fail_frame(self, frame_id, error):
        f = self.frames.get(frame_id)
        if f is None or f["failed"]:
            return  # at-most-once: later shard outcomes stay silent
        f["failed"] = True
        self.stats["typed_failures"].append((frame_id, error))

    def _retry_or_fail(self, task, reason):
        task["attempts"] += 1
        if task["attempts"] >= self.max_attempts:
            self.stats["shard_failures"] += 1
            self._fail_frame(task["frame"], reason)
            self._retire(task["frame"])
        else:
            self.stats["requeued"] += 1
            self.pending.append(task)

    def pump(self):
        progressed = True
        while progressed and self.pending:
            progressed = False
            task = self.pending[0]
            f = self.frames.get(task["frame"])
            if f is None:
                self.pending.popleft()
                progressed = True
                continue
            if f["failed"]:
                self.pending.popleft()
                self._retire(task["frame"])
                progressed = True
                continue
            if f["expires"] is not None and self.now >= f["expires"]:
                # Deadline satellite: dropped BEFORE dispatch.
                self.pending.popleft()
                self.stats["skipped_deadline"] += 1
                self._fail_frame(task["frame"], "deadline")
                self._retire(task["frame"])
                progressed = True
                continue
            candidates = [i for i, s in enumerate(self.slots)
                          if s["alive"] and len(s["inflight"]) < self.cap]
            if not any(s["alive"] for s in self.slots):
                self.pending.popleft()
                self._fail_frame(task["frame"], "workers_gone")
                self._retire(task["frame"])
                progressed = True
                continue
            if not candidates:
                return  # every live child saturated; head-of-line waits
            node = min(candidates, key=lambda i: len(self.slots[i]["inflight"]))
            self.pending.popleft()
            self.slots[node]["inflight"][(task["frame"], task["shard"])] = task
            self.stats["dispatched"] += 1
            progressed = True

    def child_dies(self, node):
        """SIGKILL analog: requeue everything in flight, respawn."""
        s = self.slots[node]
        assert s["alive"]
        s["alive"] = False
        orphans = list(s["inflight"].values())
        s["inflight"] = {}
        for t in orphans:
            self._retry_or_fail(t, "worker process died")
        self.slots[node] = {"alive": True, "inflight": {}, "last_seen": self.now}
        self.stats["respawns"] += 1

    def heartbeat(self, node):
        self.slots[node]["last_seen"] = self.now

    def check_heartbeats(self):
        for i, s in enumerate(self.slots):
            if s["alive"] and self.now - s["last_seen"] > self.hb_timeout:
                self.child_dies(i)

    def complete(self, node, frame_id, shard_id, ok=True, reason=""):
        task = self.slots[node]["inflight"].pop((frame_id, shard_id))
        f = self.frames.get(frame_id)
        if f is None:
            return
        if f["failed"]:
            self._retire(frame_id)
            return
        if ok:
            self.stats["completed"] += 1
            f["results"].append(shard_id)
            self._retire(frame_id)
        else:
            self._retry_or_fail(task, reason)

    def drain_inflight(self):
        return [(i, k) for i, s in enumerate(self.slots) for k in s["inflight"]]


def test_child_death_requeues_and_frame_completes():
    sim = SupervisorSim(workers=2, max_attempts=3, per_child_inflight=2)
    sim.submit(1, 4)
    sim.pump()
    assert sim.stats["dispatched"] == 4, "2 children x cap 2"
    victim_inflight = [k for (n, k) in sim.drain_inflight() if n == 0]
    assert victim_inflight, "child 0 must hold work"
    sim.child_dies(0)
    assert sim.stats["requeued"] == len(victim_inflight), "every orphan requeued, attempts+1"
    sim.pump()  # replacement picks the orphans back up
    for node, (fid, sid) in sim.drain_inflight():
        sim.complete(node, fid, sid)
    assert sim.pending == deque() and not sim.drain_inflight()
    assert sim.stats["completed"] == 4 and sim.stats["shard_failures"] == 0
    assert sim.stats["img_deleted"] == [1], "outstanding-zero cleanup fired exactly once"
    assert sim.stats["typed_failures"] == [], "a survivable kill fails nothing"
    assert 1 not in sim.frames
    print("supervision: child death requeues orphans; frame completes, cleanup once")


def test_attempt_exhaustion_fails_frame_exactly_once():
    sim = SupervisorSim(workers=1, max_attempts=2, per_child_inflight=4)
    sim.submit(5, 3)
    sim.pump()
    # Shard 0 fails both its attempts; shards 1-2 also report failures
    # afterwards — the frame error must still be recorded exactly once.
    sim.complete(0, 5, 0, ok=False, reason="compute failed")
    sim.pump()
    sim.complete(0, 5, 0, ok=False, reason="compute failed")  # attempt 2 of 2
    assert sim.stats["shard_failures"] == 1
    assert len(sim.stats["typed_failures"]) == 1, "typed failure is at-most-once"
    sim.complete(0, 5, 1, ok=False, reason="compute failed")
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert len(sim.stats["typed_failures"]) == 1, "later outcomes stay silent"
    assert sim.stats["img_deleted"] == [5], "failed frames still clean up exactly once"
    assert 5 not in sim.frames and not sim.pending
    print("supervision: attempts ladder bounds retries; frame fails typed exactly once")


def test_heartbeat_timeout_is_a_death():
    sim = SupervisorSim(workers=2, max_attempts=3, heartbeat_timeout=5)
    sim.submit(9, 4)
    sim.pump()
    sim.now = 4
    sim.heartbeat(1)  # child 1 is chatty; child 0 went dark at t=0
    sim.now = 6
    sim.check_heartbeats()
    assert sim.stats["respawns"] == 1, "only the silent child is declared dead"
    sim.pump()
    while sim.drain_inflight():
        for node, (fid, sid) in sim.drain_inflight():
            sim.complete(node, fid, sid)
        sim.pump()
    assert sim.stats["completed"] == 4 and sim.stats["typed_failures"] == []
    print("supervision: heartbeat silence past the timeout = child death + requeue")


def test_expired_deadline_drops_before_dispatch():
    sim = SupervisorSim(workers=2)
    sim.now = 100
    sim.submit(3, 5, expires=50)  # already blown at submit
    before = sim.stats["dispatched"]
    sim.pump()
    assert sim.stats["dispatched"] == before, "expired shards never reach a child"
    # The first expired shard fails the frame; its siblings then retire
    # through the at-most-once failed branch (supervisor.rs pump()).
    assert sim.stats["skipped_deadline"] == 1
    assert [f for (f, e) in sim.stats["typed_failures"]] == [3] and \
        sim.stats["typed_failures"][0][1] == "deadline"
    assert sim.stats["img_deleted"] == [3] and 3 not in sim.frames
    print("supervision: blown deadline drops the whole frame pre-dispatch, typed once")


if __name__ == "__main__":
    test_roundtrip_every_type()
    test_every_truncation_point_is_typed()
    test_header_corruptions_are_typed()
    test_random_bytes_never_crash_the_decoder()
    test_checksum_stable_and_bit_sensitive()
    test_child_death_requeues_and_frame_completes()
    test_attempt_exhaustion_fails_frame_exactly_once()
    test_heartbeat_timeout_is_a_death()
    test_expired_deadline_drops_before_dispatch()
    print("proc plane pre-validation: ALL OK")
