"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas kernel and every composed strategy must match the pure-jnp
oracle in ``kernels/ref.py`` exactly (integral histograms are integer
counts stored as f32, so we assert exact equality up to f32 addition
order — allclose with tight tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import binning, prescan, ref, tiled_scan, transpose, wavefront


def random_image(h, w, bins, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (h, w), 0, bins, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------


class TestOracle:
    def test_binning_is_partition(self):
        img = random_image(16, 24, 8)
        q = ref.binning(img, 8)
        # every pixel falls in exactly one bin
        np.testing.assert_array_equal(np.asarray(q.sum(axis=0)), np.ones((16, 24)))

    def test_integral_corner_is_total_histogram(self):
        img = random_image(32, 16, 4, seed=1)
        ih = ref.integral_histogram(img, 4)
        expected = np.bincount(np.asarray(img).ravel(), minlength=4)
        np.testing.assert_allclose(np.asarray(ih[:, -1, -1]), expected)

    def test_region_full_image(self):
        img = random_image(16, 16, 4, seed=2)
        ih = ref.integral_histogram(img, 4)
        h = ref.region_histogram(ih, 0, 0, 15, 15)
        expected = np.bincount(np.asarray(img).ravel(), minlength=4)
        np.testing.assert_allclose(np.asarray(h), expected)

    def test_region_single_pixel(self):
        img = random_image(8, 8, 4, seed=3)
        ih = ref.integral_histogram(img, 4)
        for r, c in [(0, 0), (3, 5), (7, 7)]:
            h = np.asarray(ref.region_histogram(ih, r, c, r, c))
            expected = np.zeros(4)
            expected[int(img[r, c])] = 1
            np.testing.assert_allclose(h, expected)

    def test_region_batch_matches_scalar(self):
        img = random_image(16, 16, 8, seed=4)
        ih = ref.integral_histogram(img, 8)
        rects = jnp.array([[0, 0, 15, 15], [2, 3, 9, 11], [5, 5, 5, 5], [0, 7, 8, 15]], jnp.int32)
        batch = np.asarray(ref.region_histogram_batch(ih, rects))
        for k, (r0, c0, r1, c1) in enumerate(np.asarray(rects)):
            np.testing.assert_allclose(
                batch[k], np.asarray(ref.region_histogram(ih, r0, c0, r1, c1))
            )

    def test_quantize_range(self):
        img = jnp.arange(256, dtype=jnp.int32).reshape(16, 16)
        q = ref.quantize(img, 16)
        assert int(q.min()) == 0 and int(q.max()) == 15


# ---------------------------------------------------------------------------
# L1 kernels vs oracle
# ---------------------------------------------------------------------------


class TestBinningKernel:
    @pytest.mark.parametrize("h,w,bins,tile", [(64, 64, 8, 32), (64, 128, 16, 64), (96, 64, 4, 32)])
    def test_matches_ref(self, h, w, bins, tile):
        img = random_image(h, w, bins)
        np.testing.assert_array_equal(
            np.asarray(binning.binning(img, bins, tile)), np.asarray(ref.binning(img, bins))
        )

    def test_rejects_indivisible(self):
        with pytest.raises(ValueError):
            binning.binning(random_image(48, 64, 4), 4, 32)


class TestPrescan:
    @pytest.mark.parametrize("rows,n", [(8, 64), (16, 128), (8, 1024)])
    def test_exclusive_scan(self, rows, n):
        x = jax.random.uniform(jax.random.PRNGKey(0), (rows, n))
        out = prescan.prescan_rows(x)
        expected = jnp.cumsum(x, axis=1) - x  # exclusive
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [48, 100, 720])
    def test_inclusive_non_pow2(self, n):
        x = jax.random.uniform(jax.random.PRNGKey(1), (8, n))
        out = prescan.inclusive_scan_rows(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.cumsum(x, axis=1)), rtol=1e-5, atol=1e-5)

    def test_rejects_non_pow2_direct(self):
        with pytest.raises(ValueError):
            prescan.prescan_rows(jnp.ones((8, 48)))

    def test_next_pow2(self):
        assert [prescan.next_pow2(n) for n in (1, 2, 3, 480, 512, 513)] == [1, 2, 4, 512, 512, 1024]


class TestTranspose:
    @pytest.mark.parametrize("h,w", [(64, 64), (64, 96), (128, 32)])
    def test_2d(self, h, w):
        x = jax.random.uniform(jax.random.PRNGKey(0), (h, w))
        np.testing.assert_array_equal(np.asarray(transpose.transpose2d(x)), np.asarray(x.T))

    @pytest.mark.parametrize("b,h,w", [(4, 64, 64), (8, 32, 96)])
    def test_3d(self, b, h, w):
        x = jax.random.uniform(jax.random.PRNGKey(0), (b, h, w))
        np.testing.assert_array_equal(
            np.asarray(transpose.transpose3d(x)), np.asarray(jnp.transpose(x, (0, 2, 1)))
        )


class TestTiledScan:
    @pytest.mark.parametrize("b,h,w,tile", [(4, 64, 64, 32), (2, 64, 128, 64), (8, 96, 32, 32)])
    def test_hscan(self, b, h, w, tile):
        x = jax.random.uniform(jax.random.PRNGKey(0), (b, h, w))
        np.testing.assert_allclose(
            np.asarray(tiled_scan.tiled_hscan(x, tile)),
            np.asarray(jnp.cumsum(x, axis=2)),
            rtol=1e-5,
            atol=1e-5,
        )

    @pytest.mark.parametrize("b,h,w,tile", [(4, 64, 64, 32), (2, 128, 64, 64), (8, 32, 96, 32)])
    def test_vscan(self, b, h, w, tile):
        x = jax.random.uniform(jax.random.PRNGKey(1), (b, h, w))
        np.testing.assert_allclose(
            np.asarray(tiled_scan.tiled_vscan(x, tile)),
            np.asarray(jnp.cumsum(x, axis=1)),
            rtol=1e-5,
            atol=1e-5,
        )


class TestWavefront:
    @pytest.mark.parametrize(
        "h,w,bins,tile",
        [(64, 64, 8, 32), (64, 96, 16, 32), (128, 64, 4, 64), (32, 32, 32, 16)],
    )
    def test_matches_ref(self, h, w, bins, tile):
        img = random_image(h, w, bins)
        out = wavefront.wf_tis(img, bins, tile)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.integral_histogram(img, bins)), atol=1e-4
        )

    def test_values_outside_bins_ignored(self):
        # padding pixels carry bin value -1 and must count in no bin
        img = jnp.full((32, 32), -1, jnp.int32)
        out = wavefront.wf_tis(img, 4, 16)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 32, 32)))

    def test_vmem_model(self):
        assert wavefront.vmem_bytes(64, 512) == 64 * 64 * 8 + 64 * 4 + 512 * 4


# ---------------------------------------------------------------------------
# L2 strategies vs oracle — all four must agree with Eq. 1
# ---------------------------------------------------------------------------


class TestStrategies:
    @pytest.mark.parametrize("name", ["cw_b", "cw_sts", "cw_tis", "wf_tis"])
    @pytest.mark.parametrize("h,w,bins", [(64, 64, 8), (64, 128, 4)])
    def test_matches_ref(self, name, h, w, bins):
        img = random_image(h, w, bins, seed=5)
        tile = 32
        out = model.STRATEGIES[name](img, bins, tile)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.integral_histogram(img, bins)), atol=1e-3
        )

    def test_strategies_mutually_equal(self):
        img = random_image(64, 64, 8, seed=6)
        outs = [np.asarray(fn(img, 8, 32)) for fn in model.STRATEGIES.values()]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-3)

    def test_pad_image(self):
        img = random_image(60, 70, 8)
        padded = model.pad_image(img, 32)
        assert padded.shape == (64, 96)
        np.testing.assert_array_equal(np.asarray(padded[:60, :70]), np.asarray(img))
        assert int(padded[60:, :].max()) == -1

    def test_padded_region_matches_unpadded(self):
        # IH of the padded image restricted to the true extent == IH of the original
        img = random_image(60, 70, 8, seed=7)
        padded = model.pad_image(img, 32)
        ih_p = np.asarray(model.wf_tis(padded, 8, 32))[:, :60, :70]
        ih = np.asarray(ref.integral_histogram(img, 8))
        np.testing.assert_allclose(ih_p, ih, atol=1e-4)


class TestRegionQueryGraph:
    def test_matches_ref_batch(self):
        img = random_image(64, 64, 8, seed=8)
        ih = ref.integral_histogram(img, 8)
        rects = jnp.array(
            [[0, 0, 63, 63], [1, 2, 30, 40], [10, 10, 10, 10], [0, 32, 31, 63]], jnp.int32
        )
        np.testing.assert_allclose(
            np.asarray(model.region_query(ih, rects)),
            np.asarray(ref.region_histogram_batch(ih, rects)),
        )

    def test_serve_graph(self):
        img = random_image(64, 64, 8, seed=9)
        rects = jnp.array([[0, 0, 63, 63], [4, 4, 20, 20]], jnp.int32)
        ih, hists = model.wf_tis_with_query(img, rects, 8, 32)
        np.testing.assert_allclose(
            np.asarray(ih), np.asarray(ref.integral_histogram(img, 8)), atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(hists[0]), np.bincount(np.asarray(img).ravel(), minlength=8))
