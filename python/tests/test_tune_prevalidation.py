"""Pre-validation of the rust/src/tune/ calibration + auto-tuning
subsystem, mirrored in plain Python (the dev container ships no Rust
toolchain; rust/tests/tune_property.rs asserts the same invariants
in-tree).

Mirrors kept in sync with the Rust sources:

1. `CostSnapshot::static_prior` / `sanitized` — paper constants per
   card; any non-finite or non-positive estimate is replaced by the
   prior, healthy estimates survive.
2. The calibrator's lock-free EWMA fold (`new = old + a*(x - old)`,
   degenerate samples dropped at the door).
3. The engine's static decision table (`Planner::plan`), the tuned
   search (`autotune::search_plan` + `model_cost`) and its dominance
   invariant: the static plan is the incumbent and only a strictly
   lower modeled cost replaces it, so the tuned plan never model-costs
   worse than the static one — under ANY snapshot, adversarial
   included.
4. The shard planner's calibrated sizing (`ShardPlan::predict_with`,
   `ShardPlanner::plan_calibrated`): budget discipline is structural
   (every candidate comes from the same budgeted `plan`), dominance is
   strict-less-than.
5. The spilled-store batched corner read (`TensorStore::query`):
   sorted-offset coalescing with a bounded gap never issues more read
   calls than corners and collapses dense runs to one call.

Run: python3 python/tests/test_tune_prevalidation.py  (or pytest)
"""

import math
import os
import random
import sys

sys.path.insert(0, os.path.dirname(__file__))
from test_shard_prevalidation import ceil_div, plan  # noqa: E402

# --- CostSnapshot mirror (rust/src/tune/mod.rs) ---

TILE_CANDIDATES = [16, 32, 64, 128]
EWMA_ALPHA = 0.25
LAUNCH_OVERHEAD_S = 5e-6
# card -> (device mem bandwidth B/s, pcie alpha s, pcie beta B/s)
CARDS = {
    "TitanX": (270e9, 8e-6, 11.5e9),
    "K40c": (230e9, 10e-6, 10.5e9),
    "C2070": (115e9, 12e-6, 5.8e9),
    "Gtx480": (142e9, 12e-6, 5.6e9),
}


def healthy(x):
    return math.isfinite(x) and x > 0.0


def static_prior(card="Gtx480"):
    bw, alpha, beta = CARDS[card]
    tput = bw / 8.0  # WF-TiS: 2 tensor passes x 4 bytes per element
    return {
        "memcpy_bps": beta,
        "tile": [tput] * len(TILE_CANDIDATES),
        "tile_tuned": [tput] * len(TILE_CANDIDATES),
        "dispatch_s": LAUNCH_OVERHEAD_S,
        "spill_lat_s": alpha,
        "spill_bps": beta,
        "samples": 0,
    }


def sanitized(s, card="Gtx480"):
    """Mirror of CostSnapshot::sanitized: estimates outside their
    physically plausible band fall back to the prior — rates (units/s)
    must sit in [1, 1e18], per-event times in [1e-12, 1e3] s, so no
    division in the cost model can overflow to infinity."""
    p = static_prior(card)
    fix = lambda x, d, lo, hi: x if math.isfinite(x) and lo <= x <= hi else d  # noqa: E731
    rate = lambda x, d: fix(x, d, 1.0, 1e18)  # noqa: E731
    time_ = lambda x, d: fix(x, d, 1e-12, 1e3)  # noqa: E731
    return {
        "memcpy_bps": rate(s["memcpy_bps"], p["memcpy_bps"]),
        "tile": [rate(x, d) for x, d in zip(s["tile"], p["tile"])],
        "tile_tuned": [rate(x, d) for x, d in zip(s["tile_tuned"], p["tile_tuned"])],
        "dispatch_s": time_(s["dispatch_s"], p["dispatch_s"]),
        "spill_lat_s": time_(s["spill_lat_s"], p["spill_lat_s"]),
        "spill_bps": rate(s["spill_bps"], p["spill_bps"]),
        "samples": s.get("samples", 0),
    }


def tile_index(tile):
    return min(range(len(TILE_CANDIDATES)), key=lambda i: abs(TILE_CANDIDATES[i] - tile))


def throughput(s, tile, kernel):
    arr = s["tile_tuned"] if kernel == "tuned" else s["tile"]
    return arr[tile_index(tile)]


def best_throughput(s):
    best = sys.float_info.min
    for x in s["tile"] + s["tile_tuned"]:
        best = max(best, x)
    return best


def ewma(old, x):
    """Mirror of calibrate.rs ewma_f64 (cell side; degenerate samples
    are rejected before this in observe_*)."""
    if not healthy(x):
        return old
    return old + EWMA_ALPHA * (x - old) if healthy(old) else x


# --- engine planner mirror (histogram/engine/planner.rs) ---

SERIAL_WORK_LIMIT = 1 << 17


def default_tile(h, w):
    m = min(h, w)
    return 64 if m >= 256 else 32 if m >= 64 else 16


def static_plan(h, w, bins, workers):
    workers = max(workers, 1)
    tile = default_tile(h, w)
    diag = min(ceil_div(h, tile), ceil_div(w, tile))
    if workers == 1 or bins * h * w < SERIAL_WORK_LIMIT:
        sched = "serial"
    elif diag == 1:
        sched = "bin_parallel" if bins > 1 else "serial"
    else:
        sched = "wavefront"
    wk = {"serial": 1, "bin_parallel": min(workers, bins), "wavefront": min(workers, max(diag, 1))}[sched]
    return {"schedule": sched, "tile": tile, "workers": wk, "kernel": "reference"}


def model_cost(s, p, h, w, bins):
    """Mirror of autotune::model_cost."""
    pixel_bins = bins * h * w
    tput = throughput(s, p["tile"], p["kernel"])
    d = s["dispatch_s"]
    if p["schedule"] == "serial":
        return pixel_bins / tput + d
    if p["schedule"] == "bin_parallel":
        wk = max(p["workers"], 1)
        return pixel_bins / tput / wk + math.ceil(bins / wk) * d
    tr, tc = ceil_div(h, p["tile"]), ceil_div(w, p["tile"])
    weff = min(max(p["workers"], 1), min(tr, tc))
    steps = max(tr * tc / weff, tr + tc - 1)
    return steps * (p["tile"] * p["tile"] * bins / tput + d)


def best_variant(s, tile):
    return "tuned" if throughput(s, tile, "tuned") > throughput(s, tile, "reference") else "reference"


def search_plan(s, h, w, bins, workers):
    """Mirror of autotune::search_plan: static incumbent, strict <."""
    workers = max(workers, 1)
    best = static_plan(h, w, bins, workers)
    best_cost = model_cost(s, best, h, w, bins)
    for tile in TILE_CANDIDATES:
        kernel = best_variant(s, tile)
        diag = min(ceil_div(h, tile), ceil_div(w, tile))
        cands = [{"schedule": "serial", "tile": tile, "workers": 1, "kernel": kernel}]
        if workers > 1 and diag >= 2:
            cands.append({"schedule": "wavefront", "tile": tile, "workers": min(workers, diag), "kernel": kernel})
        for cand in cands:
            cost = model_cost(s, cand, h, w, bins)
            if cost < best_cost:
                best, best_cost = cand, cost
    return best


# --- shard planner calibrated mirror (shard/planner.rs) ---


def predict_total_with(shards, w, spill, s, workers):
    """Mirror of ShardPlan::predict_with + aggregate: modeled wall s."""
    tput = best_throughput(s)
    sk = st = 0.0
    for (_sid, _b0, nb, _r0, nr) in shards:
        tensor_bytes = nb * nr * w * 4
        sk += nb * nr * w / tput + s["dispatch_s"]
        t = (tensor_bytes + nr * w * 4) / s["memcpy_bps"]
        if spill:
            t += s["spill_lat_s"] + tensor_bytes / s["spill_bps"]
        st += t
    return max(sk / max(workers, 1), st)


def plan_calibrated(bins, h, w, budget, workers, snap, max_group=16):
    """Mirror of ShardPlanner::plan_calibrated: enumerate power-of-two
    bin groups x oversubscription targets, strict-< replacement."""
    s = sanitized(snap)
    workers = max(workers, 1)
    spill = bins * h * w * 4 > budget
    best, per = plan(bins, h, w, budget, workers, max_group=max_group)
    best_cost = predict_total_with(best, w, spill, s, workers)
    g = 1
    while g <= max(max_group, 1):
        for over in (1, 2, 4):
            cand, _ = plan(bins, h, w, budget, workers, max_group=g, min_shards=workers * over)
            cost = predict_total_with(cand, w, spill, s, workers)
            if cost < best_cost:
                best, best_cost = cand, cost
        g *= 2
    return best, per, best_cost


# --- adversarial snapshot generator ---

HOSTILE = [float("nan"), float("inf"), float("-inf"), 0.0, -1e9, sys.float_info.min, 1e300]


def hostile_snapshot(rng):
    pick = lambda: rng.choice(HOSTILE) if rng.random() < 0.5 else rng.uniform(1e3, 1e12)  # noqa: E731
    return {
        "memcpy_bps": pick(),
        "tile": [pick() for _ in TILE_CANDIDATES],
        "tile_tuned": [pick() for _ in TILE_CANDIDATES],
        "dispatch_s": pick(),
        "spill_lat_s": pick(),
        "spill_bps": pick(),
        "samples": 7,
    }


# --- tests ---


def test_prior_and_sanitize():
    for card in CARDS:
        p = static_prior(card)
        assert all(healthy(x) for x in [p["memcpy_bps"], p["dispatch_s"], p["spill_lat_s"], p["spill_bps"]])
        assert p["tile"][0] == CARDS[card][0] / 8.0
        assert sanitized(p, card) == p, "sanitizing a healthy prior is the identity"
    rng = random.Random(5)
    for _ in range(64):
        s = sanitized(hostile_snapshot(rng))
        assert all(healthy(x) for x in s["tile"] + s["tile_tuned"])
        assert healthy(s["memcpy_bps"]) and healthy(s["spill_bps"]) and healthy(s["dispatch_s"])
    # Healthy estimates survive sanitizing untouched.
    s = static_prior()
    s["tile"] = [1.0, float("nan"), 3.0, 4.0]
    fixed = sanitized(s)
    assert fixed["tile"][0] == 1.0 and fixed["tile"][2] == 3.0
    assert fixed["tile"][1] == static_prior()["tile"][1]
    print("prior + sanitize mirror: OK")


def test_ewma_fold():
    before = static_prior()["tile"][1]
    after = ewma(before, 1e9)
    assert abs(after - (before + EWMA_ALPHA * (1e9 - before))) < 1e-6 * after
    # Degenerate samples never move anything; degenerate cells adopt.
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        assert ewma(before, bad) == before
    assert ewma(float("nan"), 42.0) == 42.0
    print("EWMA fold mirror: OK")


def test_engine_search_dominates_static():
    rng = random.Random(11)
    shapes = [(512, 512, 32, 8), (64, 64, 8, 4), (8, 4096, 32, 4), (1, 1, 1, 1), (47, 1, 3, 2)]
    for seed in range(48):
        s = sanitized(hostile_snapshot(random.Random(seed)))
        for (h, w, bins, workers) in shapes:
            tuned = search_plan(s, h, w, bins, workers)
            assert tuned["tile"] >= 1 and 1 <= tuned["workers"] <= max(workers, 1)
            if tuned["schedule"] == "serial":
                assert tuned["workers"] == 1
            fixed = static_plan(h, w, bins, workers)
            ct, cf = model_cost(s, tuned, h, w, bins), model_cost(s, fixed, h, w, bins)
            assert math.isfinite(ct) and math.isfinite(cf)
            assert ct <= cf, f"{h}x{w}x{bins}@{workers}: tuned {ct} > static {cf}"
    # A pure prior has one throughput everywhere: ties keep the static
    # decision and the reference kernel.
    prior = static_prior()
    p = search_plan(prior, 512, 512, 32, 8)
    assert p["kernel"] == "reference", "no measurement -> no tuned-kernel claim"
    _ = rng
    print("engine tuned-search dominance under adversarial snapshots: OK")


def test_shard_calibrated_budget_and_dominance():
    cases = [(32, 128, 128, 1 << 20, 4), (128, 256, 256, 1 << 20, 4), (8, 64, 64, 1 << 30, 4), (1, 1, 64, 4096, 3)]
    for seed in range(32):
        snap = hostile_snapshot(random.Random(100 + seed))
        for (bins, h, w, budget, workers) in cases:
            cal, per, cal_cost = plan_calibrated(bins, h, w, budget, workers, snap)
            assert cal, "plan must be non-empty"
            assert max(nb * nr * w * 4 for (_i, _b, nb, _r, nr) in cal) <= max(per, w * 4)
            assert math.isfinite(cal_cost) and cal_cost > 0.0
            spill = bins * h * w * 4 > budget
            static_shards, _ = plan(bins, h, w, budget, workers)
            static_cost = predict_total_with(static_shards, w, spill, sanitized(snap), workers)
            assert cal_cost <= static_cost, f"{bins}x{h}x{w}: calibrated must not model-cost worse"
    print("shard calibrated sizing: budget + dominance under adversarial snapshots: OK")


def coalesce_runs(offsets, gap=4096):
    """Mirror of TensorStore::query run coalescing: sorted corner byte
    offsets merge while the next start is within `gap` of the run end."""
    runs = 0
    end = None
    for off in sorted(offsets):
        if end is None or off > end + gap:
            runs += 1
        end = max(end, off + 4) if end is not None and off <= end + gap else off + 4
    return runs


def test_batched_corner_reads_coalesce():
    h, w, bins = 64, 64, 16
    # Eq. 2: 4 corners per bin, bin-major planes -> per-bin corners are
    # far apart, but consecutive bins' same-corner offsets stride h*w*4.
    r0, c0, r1, c1 = 9, 11, 40, 50
    offsets = []
    for b in range(bins):
        for (r, c) in [(r1, c1), (r0 - 1, c1), (r1, c0 - 1), (r0 - 1, c0 - 1)]:
            offsets.append(((b * h + r) * w + c) * 4)
    runs = coalesce_runs(offsets)
    assert runs <= len(offsets), "never more read calls than corners"
    # Same-row corner pairs sit c1-c0 apart (< gap) and coalesce, so the
    # whole rect query needs at most 2 runs per bin.
    assert runs <= 2 * bins, runs
    # A dense offset set collapses to a single positioned read.
    assert coalesce_runs(list(range(0, 4096, 4))) == 1
    # Far-apart offsets stay separate.
    assert coalesce_runs([0, 10**6, 2 * 10**6]) == 3
    print("batched spilled-query coalescing mirror: OK")


def test_tuning_cache_is_stable():
    """Mirror of the TunedPlanner cache contract: one search per
    distinct geometry, repeats served verbatim from the cache even as
    the snapshot drifts."""
    cache = {}
    hits = misses = 0
    snap = sanitized(static_prior())

    def plan_cached(h, w, bins, workers):
        nonlocal hits, misses
        key = (h, w, bins, workers)
        if key in cache:
            hits += 1
            return cache[key]
        misses += 1
        cache[key] = search_plan(snap, h, w, bins, workers)
        return cache[key]

    first = plan_cached(512, 512, 32, 8)
    snap = sanitized(hostile_snapshot(random.Random(3)))  # live drift after the search
    for _ in range(8):
        assert plan_cached(512, 512, 32, 8) == first, "cache must return a stable plan"
    assert (misses, hits, len(cache)) == (1, 8, 1)
    print("tuning-cache stability mirror: OK")


if __name__ == "__main__":
    test_prior_and_sanitize()
    test_ewma_fold()
    test_engine_search_dominates_static()
    test_shard_calibrated_budget_and_dominance()
    test_batched_corner_reads_coalesce()
    test_tuning_cache_is_stable()
    print("tune calibration pre-validation: ALL OK")
