"""Pre-validation measurement for benches/shard.rs — the dev container
ships no Rust toolchain, so this script measures the *same two
schedules* the Rust bench compares, as real multiprocessing work on
this host, and writes a clearly-labeled BENCH_shard.json at the repo
root.  CI regenerates the file with `cargo bench --bench shard`
(harness: "cargo-bench" replaces "python-prevalidation").

Schedules measured (mirroring rust/benches/shard.rs §2), on a thread
pool with GIL-releasing NumPy kernels so results move by reference as
they do in Rust:
  * serial whole-frame queue — a frame's bin-group tasks are dispatched
    to the worker pool and the next frame starts only after the frame
    fully assembles into a freshly zeroed tensor, each task cloning and
    shifting the image (the BinTaskQueue / old Server large route's
    per-job costs);
  * interleaved shard window — up to K frames' shards share the pool;
    frame N's assembly overlaps frame N+1's compute, the output buffer
    is recycled, and shards slice rather than clone (the ShardExecutor
    / FramePool schedule).

The out-of-core section streams a 128-bin tensor's strips to a real
temp file in arrival order with carry correction, tracking peak bytes
held in the parent — the TensorStore + Reassembler mirror.

The process-isolation section (benches/shard.rs §5 mirror) runs the
same schedule through real child *processes*, once per data plane:

  * file plane ("proc" row) — the frame spilled once, each shard's
    partial written to its own spill file, only paths and geometry
    crossing the process boundary; then SIGKILLs a worker mid-frame
    and recovers the frame via the supervisor's timeout-requeue
    ladder, measuring the recovery latency;
  * shm plane ("proc.shm" row, rust/src/proc/shm.rs mirror) — a
    fork-inherited mmap ring of fixed-size slots: the parent writes
    each shard's input strip into a free slot, the child computes and
    writes the partial *in place* after the strip, and only the slot
    offset and geometry cross the process boundary.  The delta between
    the two rows is the spill-file round-trip the shm plane deletes;
  * remote stream plane ("proc.remote" row, rust/src/proc/transport.rs
    mirror) — a worker process behind a real TCP socket on loopback,
    speaking the byte-exact v3 wire mirror from
    test_proc_prevalidation.py: Hello handshake, strips pushed
    parent→worker and partials pulled back as bounded Chunk frames,
    both payloads checksummed (crc32 stands in for the wire's FNV-1a
    purely for host speed — the FNV mirror itself is asserted in the
    prevalidation suite).  A mid-shard disconnect + reconnect
    (handshake again, shard re-dispatched) must still assemble the
    frame bit-identical — the reconnect ladder's data path.
"""

import json
import mmap
import multiprocessing as mp
import os
import signal
import socket
import struct
import sys
import tempfile
import threading
import time
import zlib
from collections import deque
from multiprocessing.pool import ThreadPool

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))
from test_proc_prevalidation import (  # noqa: E402
    CAPS_ALL,
    CHUNK_DATA_MAX,
    HEADER_LEN,
    PLANE_STREAM,
    VERSION as PROTO_VERSION,
    decode as proto_decode,
    encode as proto_encode,
)
from test_shard_prevalidation import ceil_div, plan  # noqa: E402
from test_tune_prevalidation import (  # noqa: E402
    plan_calibrated,
    predict_total_with,
    sanitized,
    static_prior,
)

H, W, BINS, GROUP, WORKERS, FRAMES, DISTINCT = 192, 160, 32, 4, 4, 12, 4


def make_images(bins):
    rng = np.random.default_rng(11)
    return [rng.integers(0, bins, size=(H, W)) for _ in range(DISTINCT)]


def group_task(img, b0, nb, r0, nr):
    """One shard task, ShardExecutor cost model: slice rows (no frame
    clone), shift, double cumsum (f32)."""
    sub = img[r0 : r0 + nr, :].astype(np.int64) - b0
    sub[(sub < 0) | (sub >= nb)] = -1
    onehot = (sub[None, :, :] == np.arange(nb)[:, None, None]).astype(np.float32)
    return np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2, dtype=np.float32)


def queue_task(img, b0, nb):
    """One BinTaskQueue job, device_pool cost model: clone + shift the
    WHOLE frame, then compute the group into a fresh zeroed partial."""
    shifted = img.copy().astype(np.int64) - b0
    shifted[(shifted < 0) | (shifted >= nb)] = -1
    partial = np.zeros((nb,) + img.shape, dtype=np.float32)
    onehot = (shifted[None, :, :] == np.arange(nb)[:, None, None]).astype(np.float32)
    partial[:] = np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2, dtype=np.float32)
    return partial


def supervised_group_task(img, b0, nb, r0, nr, counters, mx):
    """group_task wrapped in the ShardExecutor supervision shape: probe
    consult (occurrence counter on an inert schedule), catch-all around
    the compute, attempt accounting.  The delta vs the bare task is the
    per-attempt supervision tax benches/shard.rs §4 bounds at <2%."""
    with mx:
        counters["occ"] += 1  # FaultInjector::decide on a never-firing schedule
    try:
        out = group_task(img, b0, nb, r0, nr)
    except Exception:  # catch_unwind: count and re-raise
        with mx:
            counters["failed"] += 1
        raise
    with mx:
        counters["ok"] += 1
    return out


def proc_shard_task(img_path, h, w, b0, nb, r0, nr, out_path):
    """Child half of the proc-plane mirror (rust/src/proc/worker.rs):
    read the frame from its spill file, compute the shard, write the
    partial to the shard's own spill file.  Only paths and geometry
    cross the process boundary — never tensors."""
    img = np.fromfile(img_path, dtype="<f4").reshape(h, w).astype(np.int64)
    part = group_task(img, b0, nb, r0, nr)
    part.astype("<f4").tofile(out_path)
    return out_path


# The shm slot ring, mmap'd before the worker pool forks so children
# inherit the mapping (MAP_SHARED: both sides see each other's writes).
RING = None


def shm_shard_task(slot_off, strip_bytes, nr, w, b0, nb):
    """Child half of the shm data plane (rust/src/proc/worker.rs shm
    branch): read the input strip from the inherited ring slot, compute
    the shard, write the partial in place right after the strip.  Only
    the slot offset and geometry cross the process boundary — no file
    I/O, no pipe payloads."""
    strip = np.frombuffer(RING, dtype="<f4", count=nr * w, offset=slot_off).reshape(nr, w)
    sub = strip.astype(np.int64) - b0
    sub[(sub < 0) | (sub >= nb)] = -1
    onehot = (sub[None, :, :] == np.arange(nb)[:, None, None]).astype(np.float32)
    part = np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2, dtype=np.float32)
    end = slot_off + strip_bytes + part.nbytes
    RING[slot_off + strip_bytes : end] = part.astype("<f4").tobytes()
    return slot_off


def shm_frame(pool, img, shards, slot_bytes, free_slots, timeout=30.0):
    """One frame through the shm slot ring: the parent loads strips into
    free slots (ProcSupervisor::pump's acquire + strip write), blocks on
    the oldest in-flight shard when the ring is full, and reads each
    partial straight out of the slot on completion (on_done)."""
    rs = deque()
    out = np.zeros((BINS, H, W), dtype=np.float32)

    def drain_one():
        b0, nb, r0, nr, slot, r = rs.popleft()
        r.get(timeout=timeout)
        off = slot * slot_bytes + nr * W * 4
        out[b0 : b0 + nb, r0 : r0 + nr, :] = np.frombuffer(
            RING, dtype="<f4", count=nb * nr * W, offset=off
        ).reshape(nb, nr, W)
        free_slots.append(slot)

    for _sid, b0, nb, r0, nr in shards:
        while not free_slots:
            drain_one()  # ring full: wait for a slot, like the dispatcher
        slot = free_slots.popleft()
        off = slot * slot_bytes
        strip_bytes = nr * W * 4
        RING[off : off + strip_bytes] = np.asarray(img[r0 : r0 + nr, :], dtype="<f4").tobytes()
        rs.append((b0, nb, r0, nr, slot,
                   pool.apply_async(shm_shard_task, (off, strip_bytes, nr, W, b0, nb))))
    while rs:
        drain_one()
    return out


# --- remote stream plane (rust/src/proc/transport.rs + worker.rs
# serve_conn mirror).  The parent and the worker process share nothing
# but the socket: v3 frames from the prevalidation codec carry the
# assignment, the strip chunks (dir 0) and the partial chunks (dir 1).


def _crc32(b):
    return zlib.crc32(b) & 0xFFFFFFFF


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        got = sock.recv(n - len(buf))
        if not got:
            return None  # peer gone: EOF mid-frame is a dropped link
        buf += got
    return buf


def _send_msg(sock, msg):
    sock.sendall(proto_encode(msg))


def _recv_msg(sock):
    hdr = _recv_exact(sock, HEADER_LEN)
    if hdr is None:
        return None
    plen = struct.unpack("<I", hdr[5:9])[0]
    payload = _recv_exact(sock, plen) if plen else b""
    if plen and payload is None:
        return None
    msg, _ = proto_decode(hdr + payload)
    return msg


def _send_chunks(sock, fid, sid, direction, payload):
    total, off = len(payload), 0
    while True:
        end = min(off + CHUNK_DATA_MAX, total)
        _send_msg(sock, ("chunk", {"frame_id": fid, "shard_id": sid, "dir": direction,
                                   "offset": off, "total": total, "data": payload[off:end]}))
        if end == total:
            return
        off = end


def _serve_remote_conn(conn):
    """Worker half, one connection (worker.rs serve over a socket):
    speak Hello first, reassemble strip chunks dense and in order,
    verify the strip checksum, compute, stream the partial back."""
    try:
        _send_msg(conn, ("hello", {"version": PROTO_VERSION, "caps": CAPS_ALL, "tag": "py-worker"}))
        pending = {}
        while True:
            msg = _recv_msg(conn)
            if msg is None or msg[0] == "shutdown":
                return
            if msg[0] == "assign":
                a = msg[1]
                pending[(a["frame_id"], a["shard_id"])] = (a, bytearray())
            elif msg[0] == "chunk":
                c = msg[1]
                if c["dir"] != 0:
                    continue  # echoed partial direction: nonsense, drop
                key = (c["frame_id"], c["shard_id"])
                if key not in pending:
                    continue  # stale chunk for a requeued shard
                a, buf = pending[key]
                if c["offset"] != len(buf):
                    del pending[key]  # torn stream: the parent re-dispatches
                    continue
                buf += c["data"]
                if len(buf) < c["total"]:
                    continue
                del pending[key]
                nb, nr, w = a["nbins"], a["nrows"], a["img_w"]
                if _crc32(bytes(buf)) != a["strip_checksum"]:
                    _send_msg(conn, ("failed", {"frame_id": key[0], "shard_id": key[1],
                                                "panicked": False, "deadline": False,
                                                "reason": "strip checksum mismatch"}))
                    continue
                strip = np.frombuffer(bytes(buf), dtype="<f4").reshape(nr, w)
                sub = strip.astype(np.int64) - a["bin0"]
                sub[(sub < 0) | (sub >= nb)] = -1
                onehot = (sub[None, :, :] == np.arange(nb)[:, None, None]).astype(np.float32)
                part = np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2,
                                 dtype=np.float32)
                pbytes = part.astype("<f4").tobytes()
                _send_chunks(conn, key[0], key[1], 1, pbytes)
                _send_msg(conn, ("done", {"frame_id": key[0], "shard_id": key[1],
                                          "kernel_time_us": 0, "checksum": _crc32(pbytes),
                                          "slot": (1 << 64) - 1}))
    except (OSError, ValueError):
        pass  # dropped link: the parent's reconnect ladder owns recovery
    finally:
        conn.close()


def remote_listener_main(port_q):
    """Worker process: one listening socket, a serving thread per
    accepted connection (proc-worker --listen)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    port_q.put(srv.getsockname()[1])
    while True:
        conn, _ = srv.accept()
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        threading.Thread(target=_serve_remote_conn, args=(conn,), daemon=True).start()


def _connect_remote(addr):
    """Supervisor half of the handshake (transport.rs connect_remote):
    the worker speaks Hello first; validate its capabilities, reply."""
    s = socket.create_connection(addr, timeout=10)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    hello = _recv_msg(s)
    assert hello is not None and hello[0] == "hello", "worker must speak Hello first"
    assert hello[1]["caps"] & CAPS_ALL == CAPS_ALL, "worker missing stream/deadline caps"
    _send_msg(s, ("hello", {"version": PROTO_VERSION, "caps": CAPS_ALL, "tag": "py-supervisor"}))
    return s


def _remote_shard(sock, fid, sid, img, b0, nb, r0, nr):
    """One stream-plane dispatch: assign + strip chunks out, partial
    chunks + done back, both payloads checksum-verified."""
    strip = np.asarray(img[r0 : r0 + nr, :], dtype="<f4").tobytes()
    _send_msg(sock, ("assign", {
        "frame_id": fid, "shard_id": sid, "bin0": b0, "nbins": nb, "row0": r0, "nrows": nr,
        "img_h": H, "img_w": W, "img_path": "", "out_path": "", "plane": PLANE_STREAM,
        "slot": 0, "slot_off": 0, "ring_bytes": 0, "ring_path": "",
        "deadline_us": 0, "strip_checksum": _crc32(strip)}))
    _send_chunks(sock, fid, sid, 0, strip)
    buf = bytearray()
    while True:
        msg = _recv_msg(sock)
        if msg is None:
            raise ConnectionError("link dropped mid-shard")
        if msg[0] == "chunk" and msg[1]["dir"] == 1:
            assert msg[1]["offset"] == len(buf), "partial chunks must arrive dense"
            buf += msg[1]["data"]
        elif msg[0] == "done":
            assert len(buf) == nb * nr * W * 4, "partial truncated"
            assert _crc32(bytes(buf)) == msg[1]["checksum"], "partial checksum mismatch"
            return np.frombuffer(bytes(buf), dtype="<f4").reshape(nb, nr, W)
        elif msg[0] == "failed":
            raise RuntimeError(f"remote shard failed: {msg[1]['reason']}")


def proc_frame(pool, img_path, shards, tmp, fid, timeout=30.0, after_submit=None):
    """One frame through the process pool with the supervisor's requeue
    ladder: a shard whose worker was SIGKILLed with the task in hand
    never resolves, times out, and is resubmitted to the replenished
    pool (ProcSupervisor::child_died + pump).  Returns the assembled
    tensor and the number of requeues."""
    rs = []
    for sid, b0, nb, r0, nr in shards:
        op = os.path.join(tmp, f"f{fid}-s{sid}.bin")
        rs.append((b0, nb, r0, nr, op,
                   pool.apply_async(proc_shard_task, (img_path, H, W, b0, nb, r0, nr, op))))
    if after_submit is not None:
        after_submit()
    out = np.zeros((BINS, H, W), dtype=np.float32)
    requeues = 0
    for b0, nb, r0, nr, op, r in rs:
        for _attempt in range(3):
            try:
                r.get(timeout=timeout)
                break
            except mp.TimeoutError:
                requeues += 1
                r = pool.apply_async(proc_shard_task, (img_path, H, W, b0, nb, r0, nr, op))
        else:
            raise RuntimeError("shard lost after max attempts")
        out[b0 : b0 + nb, r0 : r0 + nr, :] = np.fromfile(op, dtype="<f4").reshape(nb, nr, W)
        os.unlink(op)
    return out, requeues


def serial_queue_schedule(pool, imgs, frames, shards):
    """Whole-frame serialization: dispatch, barrier, assemble into a
    freshly zeroed tensor, repeat (BinTaskQueue::compute)."""
    t0 = time.perf_counter()
    for f in range(frames):
        img = imgs[f % len(imgs)]
        rs = [pool.apply_async(queue_task, (img, b0, nb)) for (_, b0, nb, _r0, _nr) in shards]
        parts = [r.get() for r in rs]  # barrier
        out = np.zeros((BINS, H, W), dtype=np.float32)  # per-frame zeros, like the queue
        for (_, b0, nb, _r0, _nr), p in zip(shards, parts):
            out[b0 : b0 + nb, :, :] = p
    return frames / max(time.perf_counter() - t0, 1e-9)


def interleaved_schedule(pool, imgs, frames, shards, window, task=group_task, extra=()):
    """Sliding window of frames in flight; drain in submission order;
    recycled output buffers (FramePool)."""
    t0 = time.perf_counter()
    inflight = deque()
    submitted = done = 0
    outs = [np.zeros((BINS, H, W), dtype=np.float32) for _ in range(window)]
    while done < frames:
        while len(inflight) < window and submitted < frames:
            img = imgs[submitted % len(imgs)]
            inflight.append(
                [pool.apply_async(task, (img, b0, nb, r0, nr) + tuple(extra)) for (_, b0, nb, r0, nr) in shards]
            )
            submitted += 1
        rs = inflight.popleft()
        out = outs[done % window]
        for (_, b0, nb, r0, nr), r in zip(shards, rs):
            out[b0 : b0 + nb, r0 : r0 + nr, :] = r.get()
        done += 1
    return frames / max(time.perf_counter() - t0, 1e-9)


def out_of_core_spill(pool, img, bins, budget):
    """Stream strips to disk in arrival order with carry correction,
    tracking peak bytes held in the parent (partials + carries)."""
    shards, per = plan(bins, H, W, budget, WORKERS)
    path = tempfile.mktemp(prefix="inthist-py-spill-")
    held = peak = 0
    next_row, carry, parked = {}, {}, {}
    t0 = time.perf_counter()
    with open(path, "wb") as fh:
        fh.truncate(bins * H * W * 4)

        def commit(sid, part):
            nonlocal held
            _, b0, nb, r0, nr = shards[sid]
            c = carry.get(b0)
            corrected = part if c is None else part + c[:, None, :]
            for k in range(nb):
                fh.seek((((b0 + k) * H + r0) * W) * 4)
                fh.write(corrected[k].astype("<f4").tobytes())
            if r0 + nr < H:
                if c is None:
                    held += nb * W * 4
                carry[b0] = corrected[:, -1, :].copy()
            elif c is not None:
                held -= nb * W * 4
                del carry[b0]
            next_row[b0] = r0 + nr
            held -= part.nbytes

        # Bounded in-flight window, like the executor's sync channel.
        inflight = deque()
        submitted = 0
        while submitted < len(shards) or inflight:
            while len(inflight) < 2 * WORKERS and submitted < len(shards):
                _, b0, nb, r0, nr = shards[submitted]
                inflight.append((submitted, pool.apply_async(group_task, (img, b0, nb, r0, nr))))
                submitted += 1
            sid, r = inflight.popleft()
            part = r.get()
            held += part.nbytes
            peak = max(peak, held)
            _, b0, nb, r0, nr = shards[sid]
            if r0 != next_row.get(b0, 0):
                parked[(b0, r0)] = (sid, part)
                continue
            commit(sid, part)
            peak = max(peak, held)
            while (b0, next_row[b0]) in parked:
                psid, ppart = parked.pop((b0, next_row[b0]))
                commit(psid, ppart)
    wall = time.perf_counter() - t0
    # Spot-check Eq. 2 corner reads against a dense recompute.
    dense = np.cumsum(
        np.cumsum((img[None] == np.arange(bins)[:, None, None]).astype(np.float32), 1, dtype=np.float32),
        2,
        dtype=np.float32,
    )
    tq0 = time.perf_counter()
    nq = 64
    with open(path, "rb") as fh:
        def corner(b, r, c):
            fh.seek(((b * H + r) * W + c) * 4)
            return np.frombuffer(fh.read(4), dtype="<f4")[0]

        for i in range(nq):
            r0, c0 = (i * 3) % (H // 2), (i * 5) % (W // 2)
            r1, c1 = r0 + H // 2 - 1, c0 + W // 2 - 1
            for b in range(0, bins, 16):
                v = corner(b, r1, c1) - corner(b, r0 - 1, c1) - corner(b, r1, c0 - 1) + corner(b, r0 - 1, c0 - 1) \
                    if r0 > 0 and c0 > 0 else None
                if v is not None:
                    ref = dense[b, r1, c1] - dense[b, r0 - 1, c1] - dense[b, r1, c0 - 1] + dense[b, r0 - 1, c0 - 1]
                    assert v == np.float32(ref), "spilled corner query deviates"
    query_rate = nq / max(time.perf_counter() - tq0, 1e-9)
    os.unlink(path)
    return len(shards), wall, peak, query_rate


def measure_snapshot(imgs):
    """Host-measured CostSnapshot mirror (the Calibrator::calibrate
    analog): memcpy bandwidth from a real buffer copy, kernel
    throughput from timing one shard task, spill read latency/bandwidth
    from a real temp file.  Dispatch overhead keeps the paper prior —
    it is below this harness's timer resolution, as in Rust."""
    snap = static_prior()
    src = np.zeros(8 << 20, dtype=np.uint8)
    dst = np.empty_like(src)
    np.copyto(dst, src)  # warm + fault pages in
    t0 = time.perf_counter()
    for _ in range(2):
        np.copyto(dst, src)
    snap["memcpy_bps"] = 2 * src.nbytes / max(time.perf_counter() - t0, 1e-9)
    group_task(imgs[0], 0, GROUP, 0, H)  # warm
    t0 = time.perf_counter()
    group_task(imgs[0], 0, GROUP, 0, H)
    tput = GROUP * H * W / max(time.perf_counter() - t0, 1e-9)
    snap["tile"] = [tput] * 4
    snap["tile_tuned"] = [tput] * 4
    path = tempfile.mktemp(prefix="inthist-py-cal-")
    with open(path, "wb") as fh:
        fh.write(b"\x00" * (128 << 10))
    with open(path, "rb") as fh:
        t0 = time.perf_counter()
        reads = 32
        for r in range(reads):
            fh.seek(r * 4096)
            fh.read(4096)
        snap["spill_lat_s"] = max(time.perf_counter() - t0, 1e-9) / reads
        fh.seek(0)
        t0 = time.perf_counter()
        data = fh.read()
        snap["spill_bps"] = len(data) / max(time.perf_counter() - t0, 1e-9)
    os.unlink(path)
    snap["samples"] = 1
    return sanitized(snap)


def main():
    imgs = make_images(BINS)
    # Interleave comparison uses the same 4-bin full-row decomposition
    # on both sides, like the Rust bench.
    shards, _ = plan(BINS, H, W, 64 << 20, WORKERS, max_group=GROUP)
    assert len(shards) == BINS // GROUP, shards

    with ThreadPool(WORKERS) as pool:
        serial_queue_schedule(pool, imgs, 2, shards)  # warm-up
        serial_fps = serial_queue_schedule(pool, imgs, FRAMES, shards)
        by_window = {}
        for window in (1, 2, 4):
            by_window[window] = interleaved_schedule(pool, imgs, FRAMES, shards, window)

        # Calibrated plan sweep (the benches/shard.rs §sweep mirror):
        # each budget row carries both the static plan's measured fps
        # and the calibrated plan's, plus both modeled walls under the
        # measured snapshot — the dominance check CI re-asserts.
        snap = measure_snapshot(imgs)
        sweep = []
        for budget in (1 << 30, 4 << 20, 1 << 20, 256 << 10):
            pshards, _ = plan(BINS, H, W, budget, WORKERS)
            fps = interleaved_schedule(pool, imgs, FRAMES // 2, pshards, 2)
            cal_shards, _, model_cal = plan_calibrated(BINS, H, W, budget, WORKERS, snap)
            fps_cal = interleaved_schedule(pool, imgs, FRAMES // 2, cal_shards, 2)
            spill = BINS * H * W * 4 > budget
            model_static = predict_total_with(pshards, W, spill, snap, WORKERS)
            g = pshards[0][2]
            strip = pshards[0][4]
            sweep.append({"budget": budget, "shards": len(pshards), "group": g,
                          "strip_rows": strip, "fps": round(fps, 2),
                          "shards_calibrated": len(cal_shards),
                          "fps_calibrated": round(fps_cal, 2),
                          "model_wall_static_s": round(model_static, 6),
                          "model_wall_calibrated_s": round(model_cal, 6)})
        cal_dominates = all(
            r["model_wall_calibrated_s"] <= r["model_wall_static_s"] for r in sweep
        )

        oc_bins, oc_budget = 128, 1 << 20
        oc_img = make_images(oc_bins)[0]
        oc_shards, oc_wall, oc_peak, oc_qps = out_of_core_spill(pool, oc_img, oc_bins, oc_budget)

        # Supervision overhead (benches/shard.rs §4): same interleaved
        # schedule with every task wrapped in the supervisor shape
        # (probe consult + catch + attempt accounting) on a schedule
        # that never fires.  Best-of-two on each side to damp noise.
        mx = threading.Lock()
        counters = {"occ": 0, "ok": 0, "failed": 0}
        rounds = 4
        plain_fps = sup_fps = 0.0
        for _ in range(rounds):  # alternate sides: best-of-N damps pool-scheduling noise
            plain_fps = max(plain_fps, interleaved_schedule(pool, imgs, FRAMES, shards, 2))
            sup_fps = max(
                sup_fps,
                interleaved_schedule(
                    pool, imgs, FRAMES, shards, 2, task=supervised_group_task, extra=(counters, mx)
                ),
            )
        assert counters["occ"] == counters["ok"] == rounds * FRAMES * len(shards), counters
        assert counters["failed"] == 0
        overhead_pct = 100.0 * (plain_fps - sup_fps) / max(plain_fps, 1e-9)

    # --- process isolation (benches/shard.rs §5): same schedule, real
    # child processes, file data plane, SIGKILL recovery ---
    proc_workers = 2
    ctx = mp.get_context("fork")
    tmp = tempfile.mkdtemp(prefix="inthist-py-proc-")
    img_paths = []
    for i, img in enumerate(imgs):
        p = os.path.join(tmp, f"img{i}.bin")
        np.asarray(img, dtype="<f4").tofile(p)
        img_paths.append(p)
    with ctx.Pool(proc_workers) as ppool:
        proc_frame(ppool, img_paths[0], shards, tmp, 9000)  # warm-up
        t0 = time.perf_counter()
        for f in range(FRAMES):
            proc_frame(ppool, img_paths[f % DISTINCT], shards, tmp, f)
        proc_fps = FRAMES / max(time.perf_counter() - t0, 1e-9)

        # Bit-identity across the process boundary, one frame.
        tensor, _ = proc_frame(ppool, img_paths[0], shards, tmp, 9050)
        dense = np.cumsum(
            np.cumsum((imgs[0][None] == np.arange(BINS)[:, None, None]).astype(np.float32), 1, dtype=np.float32),
            2, dtype=np.float32,
        )
        assert np.array_equal(tensor, dense), "proc plane deviates from dense oracle"

        t0 = time.perf_counter()
        proc_frame(ppool, img_paths[0], shards, tmp, 9100)
        clean_frame_ms = (time.perf_counter() - t0) * 1e3

        # SIGKILL a worker with the frame's shards in flight (mirrors
        # FaultSite::WorkerAbort).  The 5 ms delay lands the kill inside
        # a shard compute, not inside the task-queue read; the 1 s get
        # timeout is the heartbeat-timeout analog that detects the loss.
        before_pids = {w.pid for w in ppool._pool}

        def kill_one():
            time.sleep(0.005)
            os.kill(next(iter(before_pids)), signal.SIGKILL)

        t0 = time.perf_counter()
        killed_tensor, requeues = proc_frame(
            ppool, img_paths[0], shards, tmp, 9200, timeout=1.0, after_submit=kill_one
        )
        killed_frame_ms = (time.perf_counter() - t0) * 1e3
        assert np.array_equal(killed_tensor, dense), "frame across a SIGKILL deviates"
        time.sleep(0.2)  # let the pool's maintenance thread replenish
        respawns = len({w.pid for w in ppool._pool} - before_pids)
    for p in img_paths:
        os.unlink(p)
    os.rmdir(tmp)
    respawn_recovery_ms = max(killed_frame_ms - clean_frame_ms, 0.0)
    isolation_tax_pct = 100.0 * (plain_fps - proc_fps) / max(plain_fps, 1e-9)

    # --- process isolation, shm data plane (the tentpole's measured
    # win): the identical schedule with the spill-file round-trip
    # replaced by a fork-inherited mmap slot ring ---
    global RING
    ring_dir = "/dev/shm" if os.path.isdir("/dev/shm") else tempfile.gettempdir()
    ring_path = os.path.join(ring_dir, f"inthist-py-ring-{os.getpid()}.bin")
    slot_bytes = max(nr * W * 4 + nb * nr * W * 4 for (_s, _b0, nb, _r0, nr) in shards)
    nslots = 2 * proc_workers
    with open(ring_path, "wb") as fh:
        fh.truncate(nslots * slot_bytes)
    ring_file = open(ring_path, "r+b")
    RING = mmap.mmap(ring_file.fileno(), nslots * slot_bytes)
    shm_dispatched = 0
    try:
        with ctx.Pool(proc_workers) as spool:  # forks AFTER the mmap: children inherit it
            free_slots = deque(range(nslots))
            shm_frame(spool, imgs[0], shards, slot_bytes, free_slots)  # warm-up
            t0 = time.perf_counter()
            for f in range(FRAMES):
                shm_frame(spool, imgs[f % DISTINCT], shards, slot_bytes, free_slots)
            shm_fps = FRAMES / max(time.perf_counter() - t0, 1e-9)
            shm_dispatched = (FRAMES + 2) * len(shards)
            # Bit-identity through the ring, against the same oracle.
            shm_tensor = shm_frame(spool, imgs[0], shards, slot_bytes, free_slots)
            assert np.array_equal(shm_tensor, dense), "shm plane deviates from dense oracle"
    finally:
        RING.close()
        ring_file.close()
        os.unlink(ring_path)
    shm_tax_pct = 100.0 * (plain_fps - shm_fps) / max(plain_fps, 1e-9)

    # --- process isolation, remote stream plane (PR 10 tentpole): the
    # identical schedule against a worker process behind a TCP socket
    # on loopback, two connections over one listener exactly like the
    # Rust loopback tests — nothing crosses but checksummed frames ---
    port_q = ctx.Queue()
    listener = ctx.Process(target=remote_listener_main, args=(port_q,), daemon=True)
    listener.start()
    addr = ("127.0.0.1", port_q.get(timeout=10))
    socks = [_connect_remote(addr) for _ in range(proc_workers)]
    conn_shards = [list(shards)[ci::proc_workers] for ci in range(proc_workers)]
    remote_reconnects = 0
    try:
        with ThreadPool(proc_workers) as rpool:
            def conn_run(ci, fid, img):
                return [(b0, nb, r0, nr, _remote_shard(socks[ci], fid, sid, img, b0, nb, r0, nr))
                        for (sid, b0, nb, r0, nr) in conn_shards[ci]]

            def remote_frame(fid):
                img = imgs[fid % DISTINCT]
                out = np.zeros((BINS, H, W), dtype=np.float32)
                rs = [rpool.apply_async(conn_run, (ci, fid, img)) for ci in range(proc_workers)]
                for r in rs:
                    for b0, nb, r0, nr, part in r.get(timeout=60):
                        out[b0 : b0 + nb, r0 : r0 + nr, :] = part
                return out

            remote_frame(0)  # warm-up
            t0 = time.perf_counter()
            for f in range(FRAMES):
                remote_frame(f)
            remote_fps = FRAMES / max(time.perf_counter() - t0, 1e-9)
            stream_dispatched = (FRAMES + 2) * len(shards)
            rtensor = remote_frame(0)
            assert np.array_equal(rtensor, dense), "remote stream plane deviates from dense oracle"

        # Mid-shard disconnect: dispatch a shard, drop the link before
        # its partial comes back, reconnect (Hello handshake again) and
        # re-dispatch — the frame must still assemble bit-identical
        # (the reconnect ladder's data path, proc_property.rs mirror).
        out = np.zeros((BINS, H, W), dtype=np.float32)
        for i, (sid, b0, nb, r0, nr) in enumerate(shards):
            ci = i % proc_workers
            if i == 1:
                strip = np.asarray(imgs[0][r0 : r0 + nr, :], dtype="<f4").tobytes()
                _send_msg(socks[ci], ("assign", {
                    "frame_id": 9300, "shard_id": sid, "bin0": b0, "nbins": nb,
                    "row0": r0, "nrows": nr, "img_h": H, "img_w": W, "img_path": "",
                    "out_path": "", "plane": PLANE_STREAM, "slot": 0, "slot_off": 0,
                    "ring_bytes": 0, "ring_path": "", "deadline_us": 0,
                    "strip_checksum": _crc32(strip)}))
                _send_chunks(socks[ci], 9300, sid, 0, strip)
                socks[ci].close()  # mid-shard drop: the partial never lands
                socks[ci] = _connect_remote(addr)
                remote_reconnects += 1
            out[b0 : b0 + nb, r0 : r0 + nr, :] = _remote_shard(
                socks[ci], 9300, sid, imgs[0], b0, nb, r0, nr
            )
            stream_dispatched += 1
        assert np.array_equal(out, dense), "frame across a dropped link deviates"
        assert remote_reconnects == 1
    finally:
        for s in socks:
            try:
                _send_msg(s, ("shutdown", {}))
            except OSError:
                pass
            s.close()
        listener.terminate()
        listener.join(timeout=5)
    remote_tax_pct = 100.0 * (plain_fps - remote_fps) / max(plain_fps, 1e-9)

    speed2 = by_window[2] / serial_fps
    report = {
        "bench": "shard",
        "harness": "python-prevalidation",
        "note": "Measured by python/bench_shard_sim.py (no Rust toolchain in the dev "
                "container): same schedules, real multiprocessing work on this host. "
                "CI regenerates this file with `cargo bench --bench shard`.",
        "reps": FRAMES // 4,
        "config": {"h": H, "w": W, "bins": BINS, "workers": WORKERS,
                   "frames": FRAMES, "group": GROUP},
        "plan_sweep": sweep,
        "interleave": {
            "serial_queue_fps": round(serial_fps, 2),
            "shard_fps_by_inflight": {str(k): round(v, 2) for k, v in by_window.items()},
        },
        "out_of_core": {
            "bins": oc_bins,
            "tensor_bytes": oc_bins * H * W * 4,
            "budget_bytes": oc_budget,
            "shards": oc_shards,
            "wall_s": round(oc_wall, 4),
            "peak_resident_bytes": oc_peak,
            "within_budget": oc_peak <= oc_budget,
            "spilled_queries_per_s": round(oc_qps),
        },
        "supervision": {
            "fault_feature_compiled": False,
            "fps": round(plain_fps, 2),
            "probed_fps": round(sup_fps, 2),
            "overhead_pct": round(overhead_pct, 3),
            "within_2pct": overhead_pct < 2.0,
        },
        "proc": {
            "workers": proc_workers,
            "data_plane": "file",
            "fps_in_process": round(plain_fps, 2),
            "fps_multi_process": round(proc_fps, 2),
            "isolation_tax_pct": round(isolation_tax_pct, 2),
            "clean_frame_ms": round(clean_frame_ms, 2),
            "killed_frame_ms": round(killed_frame_ms, 2),
            "respawn_recovery_ms": round(respawn_recovery_ms, 2),
            "respawns": respawns,
            "requeues": requeues,
        },
        "proc.shm": {
            "workers": proc_workers,
            "data_plane": "shm" if ring_dir == "/dev/shm" else "file-backed-mmap",
            "fps_in_process": round(plain_fps, 2),
            "fps_multi_process": round(shm_fps, 2),
            "isolation_tax_pct": round(shm_tax_pct, 2),
            "shm_dispatched": shm_dispatched,
            "shm_fallbacks": 0,
            "slots_reclaimed": 0,
            "ring_slots": nslots,
            "ring_bytes": nslots * slot_bytes,
        },
        "proc.remote": {
            "workers": proc_workers,
            "data_plane": "stream",
            "transport": "tcp-loopback",
            "fps_in_process": round(plain_fps, 2),
            "fps_multi_process": round(remote_fps, 2),
            "isolation_tax_pct": round(remote_tax_pct, 2),
            "stream_dispatched": stream_dispatched,
            "chunk_data_max": CHUNK_DATA_MAX,
            "reconnects": remote_reconnects,
            "disconnect_frame_bit_identical": True,
        },
        "derived": {
            "interleaved_2_inflight_vs_serial_queue": round(speed2, 3),
            "interleaved_beats_serial_queue": by_window[2] > serial_fps,
            "calibrated_matches_or_beats_static_all_rows": cal_dominates,
            "shm_vs_file_fps_ratio": round(shm_fps / max(proc_fps, 1e-9), 3),
            "shm_tax_below_file_tax": shm_tax_pct < isolation_tax_pct,
            "stream_vs_file_fps_ratio": round(remote_fps / max(proc_fps, 1e-9), 3),
            "calibration_samples": snap["samples"],
        },
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_shard.json")
    with open(out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")
    print(json.dumps(report["interleave"], indent=2))
    print(json.dumps(report["derived"], indent=2))
    print(json.dumps(report["out_of_core"], indent=2))
    print(json.dumps(report["supervision"], indent=2))
    print(json.dumps(report["proc"], indent=2))
    print(json.dumps(report["proc.shm"], indent=2))
    print(json.dumps(report["proc.remote"], indent=2))
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
