"""Pre-validation measurement for benches/hotpath.rs — the dev
container ships no Rust toolchain, so this script measures NumPy
analogs of the hot paths on this host and writes a clearly-labeled
BENCH_hotpath.json at the repo root.  CI regenerates the file with
`cargo bench --bench hotpath` (harness: "cargo-bench" replaces
"python-prevalidation").

What is real measurement vs model here:

* single-thread / thread-scaling / kernel-variant / frame-pool /
  region-query rows are real NumPy timings of the analogous data
  movement (one-hot + double cumsum is Algorithm 1's arithmetic);
* the calibrated-vs-static section is the *model* comparison from the
  python mirror of rust/src/tune/ (tests/test_tune_prevalidation.py):
  a host-measured snapshot costs the static planner's choice and the
  tuned search's choice, and reports the ratio — the Rust bench
  replaces this with wall-clock engine runs.
"""

import json
import os
import sys
import time
from multiprocessing.pool import ThreadPool

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))
from test_tune_prevalidation import (  # noqa: E402
    model_cost,
    sanitized,
    search_plan,
    static_plan,
    static_prior,
)

H, W, BINS, LOW_BINS, THREADS = 512, 512, 32, 4, 4
REPS = int(os.environ.get("BENCH_REPS", "5"))


def make_image(bins, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(0, bins, size=(H, W))


def bench(fn, reps=REPS):
    """Median/p10/p90 milliseconds over `reps` timed runs (1 warmup)."""
    fn()
    times = sorted(
        (lambda t0: (fn(), (time.perf_counter() - t0) * 1e3)[1])(time.perf_counter())
        for _ in range(reps)
    )
    return times[len(times) // 2], times[0], times[-1]


def row(group, name, med, p10, p90):
    return {
        "group": group,
        "name": name,
        "median_ns": round(med * 1e6),
        "median_ms": round(med, 4),
        "p10_ms": round(p10, 4),
        "p90_ms": round(p90, 4),
        "fps": round(1e3 / max(med, 1e-9), 2),
    }


def image_major(img, bins, out=None):
    """Algorithm 1 arithmetic, image-major: one-hot + double cumsum."""
    onehot = (img[None, :, :] == np.arange(bins)[:, None, None]).astype(np.float32)
    np.cumsum(onehot, axis=1, dtype=np.float32, out=onehot)
    return np.cumsum(onehot, axis=2, dtype=np.float32, out=out)


def kernel_reference(img, bins):
    """Reference-kernel analog: fresh allocations on every pass."""
    onehot = (img[None, :, :] == np.arange(bins)[:, None, None]).astype(np.float32)
    return np.cumsum(np.cumsum(onehot, axis=1, dtype=np.float32), axis=2, dtype=np.float32)


def kernel_tuned(img, bins, onehot, out):
    """Tuned-kernel analog: preallocated buffers, in-place passes (the
    blocked+unrolled kernel's no-realloc, cache-resident shape)."""
    np.equal(img[None, :, :], np.arange(bins)[:, None, None], out=onehot)
    np.cumsum(onehot, axis=1, dtype=np.float32, out=out)
    np.cumsum(out, axis=2, dtype=np.float32, out=out)
    return out


def bin_parallel(pool, img, bins, threads):
    """One task per bin-plane chunk, like integral_histogram_parallel."""
    chunks = np.array_split(np.arange(bins), threads)

    def task(planes):
        onehot = (img[None, :, :] == planes[:, None, None]).astype(np.float32)
        np.cumsum(onehot, axis=1, dtype=np.float32, out=onehot)
        return np.cumsum(onehot, axis=2, dtype=np.float32)

    return [r.get() for r in [pool.apply_async(task, (c,)) for c in chunks if len(c)]]


def main():
    img = make_image(BINS)
    img4 = make_image(LOW_BINS)
    report_rows = []

    # --- single-thread variants ---
    med, p10, p90 = bench(lambda: image_major(img, BINS))
    report_rows.append(row("single_thread", "image-major (1 image pass)", med, p10, p90))

    # --- thread scaling ---
    with ThreadPool(THREADS) as pool:
        par_meds = {}
        for threads in (1, 2, 4):
            med, p10, p90 = bench(lambda t=threads: bin_parallel(pool, img, BINS, t))
            par_meds[threads] = med
            report_rows.append(
                row("thread_scaling", f"bin-parallel, {threads} threads", med, p10, p90)
            )

        # --- engine vs baseline (fused single-sweep analog vs bin-parallel) ---
        onehot = np.empty((BINS, H, W), dtype=np.float32)
        out = np.empty((BINS, H, W), dtype=np.float32)
        wf_med, p10, p90 = bench(lambda: kernel_tuned(img, BINS, onehot, out))
        report_rows.append(
            row("engine_vs_baseline", "engine fused sweep, 32 bins (pooled)", wf_med, p10, p90)
        )
        par4_32 = par_meds[4]
        report_rows.append(
            row("engine_vs_baseline", "baseline bin-parallel, 4 threads, 32 bins",
                par4_32, par4_32, par4_32)
        )
        onehot4 = np.empty((LOW_BINS, H, W), dtype=np.float32)
        out4 = np.empty((LOW_BINS, H, W), dtype=np.float32)
        wf4_med, p10, p90 = bench(lambda: kernel_tuned(img4, LOW_BINS, onehot4, out4))
        report_rows.append(
            row("engine_vs_baseline", "engine fused sweep, 4 bins (pooled)", wf4_med, p10, p90)
        )
        par4_med, p10, p90 = bench(lambda: bin_parallel(pool, img4, LOW_BINS, THREADS))
        report_rows.append(
            row("engine_vs_baseline", "baseline bin-parallel, 4 threads, 4 bins",
                par4_med, p10, p90)
        )

    speedup32 = par4_32 / wf_med
    speedup4 = par4_med / wf4_med

    # --- frame pool steady state: preallocated cycle, zero new buffers ---
    allocated, reused = 1, 0

    def pooled_cycle():
        nonlocal reused
        image_major(img, BINS, out=out)
        reused += 1

    med, p10, p90 = bench(pooled_cycle)
    report_rows.append(
        row("frame_pool", "pooled frame cycle (acquire+scan+release)", med, p10, p90)
    )

    # --- region queries (Eq. 2 corner reads on the assembled tensor) ---
    ih = image_major(img, BINS)

    def queries():
        acc = np.float32(0)
        for i in range(1000):
            r0, c0 = (i * 7) % 300 + 1, (i * 13) % 300 + 1
            r1, c1 = r0 + 64 + i % 100, c0 + 64 + i % 64
            acc += (
                ih[:, r1, c1] - ih[:, r0 - 1, c1] - ih[:, r1, c0 - 1] + ih[:, r0 - 1, c0 - 1]
            ).sum()
        return acc

    med, p10, p90 = bench(queries)
    report_rows.append(row("region_query", "1000 region queries (Eq. 2)", med, p10, p90))

    # --- tuned kernel variant vs reference ---
    kref_med, p10r, p90r = bench(lambda: kernel_reference(img, BINS))
    report_rows.append(row("calibrated_vs_static", "kernel reference, tile 64", kref_med, p10r, p90r))
    ktun_med, p10t, p90t = bench(lambda: kernel_tuned(img, BINS, onehot, out))
    report_rows.append(
        row("calibrated_vs_static", "kernel tuned (blocked+unrolled), tile 64", ktun_med, p10t, p90t)
    )
    kernel_ratio = kref_med / max(ktun_med, 1e-9)

    # --- calibrated vs static planner: the model comparison, costed
    # with a host-measured snapshot (the python Calibrator analog) ---
    snap = static_prior()
    elems = BINS * H * W
    t0 = time.perf_counter()
    image_major(img, BINS, out=out)
    tput = elems / max(time.perf_counter() - t0, 1e-9)
    snap["tile"] = [tput] * 4
    snap["tile_tuned"] = [tput * kernel_ratio] * 4
    src = np.zeros(8 << 20, dtype=np.uint8)
    dst = np.empty_like(src)
    np.copyto(dst, src)
    t0 = time.perf_counter()
    np.copyto(dst, src)
    snap["memcpy_bps"] = src.nbytes / max(time.perf_counter() - t0, 1e-9)
    snap["samples"] = 1
    snap = sanitized(snap)

    cache = {}
    hits = misses = 0
    cal_ratios = {}
    for (h, w, bins) in [(512, 512, 32), (512, 512, 4), (128, 2048, 16)]:
        for _ in range(3):  # repeats exercise the cache like a frame stream
            key = (h, w, bins, THREADS)
            if key in cache:
                hits += 1
                tuned = cache[key]
            else:
                misses += 1
                tuned = cache[key] = search_plan(snap, h, w, bins, THREADS)
        fixed = static_plan(h, w, bins, THREADS)
        cs = model_cost(snap, fixed, h, w, bins)
        ct = model_cost(snap, tuned, h, w, bins)
        cal_ratios[f"{h}x{w}x{bins}"] = round(cs / max(ct, 1e-12), 3)
        report_rows.append(
            row("calibrated_vs_static", f"model static plan {h}x{w}x{bins}", cs * 1e3, cs * 1e3, cs * 1e3)
        )
        report_rows.append(
            row("calibrated_vs_static", f"model calibrated plan {h}x{w}x{bins}", ct * 1e3, ct * 1e3, ct * 1e3)
        )

    report = {
        "bench": "hotpath",
        "harness": "python-prevalidation",
        "note": "Measured by python/bench_hotpath_sim.py (no Rust toolchain in the dev "
                "container): NumPy analogs of the hot paths plus the tune-model mirror "
                "for the calibrated-vs-static section. CI regenerates this file with "
                "`cargo bench --bench hotpath`.",
        "reps": REPS,
        "config": {"h": H, "w": W, "bins": BINS, "low_bins": LOW_BINS, "threads": THREADS},
        "rows": report_rows,
        "derived": {
            "wavefront_vs_binparallel_32bins_4threads": round(speedup32, 3),
            "wavefront_vs_binparallel_4bins_4threads": round(speedup4, 3),
            "frame_pool": {"allocated": allocated, "reused": reused},
            "calibrated_vs_static": cal_ratios,
            "tuned_kernel_vs_reference_tile64": round(kernel_ratio, 3),
            "tune": {"hits": hits, "misses": misses, "cached": len(cache),
                     "calibration_samples": snap["samples"]},
        },
    }
    assert all(r >= 1.0 for r in cal_ratios.values()), (
        "calibrated plan must match or beat static in model terms", cal_ratios)
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_hotpath.json")
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")
    print(json.dumps(report["derived"], indent=2))
    print(f"wrote {os.path.abspath(out_path)}")


if __name__ == "__main__":
    main()
